"""Wire-format subsystem contracts (repro.comm).

Pins:
* codec roundtrip exactness: decode(encode(mask)) is exact for every
  codec, serialized length equals the measured byte formula, and the
  traced (jnp) formulas equal the numpy ones bit for bit;
* value-codec contracts: fp32 lossless, fp16 cast-exact, int8 stochastic
  rounding within one scale step and deterministic (keyed);
* accounting identity: with the default CommConfig (dense codec, 32-bit
  values) ``wire_bytes == uploaded_bytes`` EXACTLY on all four execution
  paths (reference loop, batched engine, grouped engine, multi-round
  scan) and the learning state matches a comm-less run bit for bit;
* sparse-codec parity: loop vs engine vs scanned agree on wire bytes
  (integer overheads — exact across XLA programs) and learning state;
* degenerate settings: zero-density uploads cost header-only bytes,
  full-density uploads make the dense fallback beat index coding, and a
  dead-uplink client under codec-measured bytes is cut by the deadline
  policy;
* the bitmask/index crossover sits where the byte formulas say (~1/8).
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm import codecs, payload, quantize
from repro.comm.payload import CommConfig, WireSpec, account_uplink
from repro.core import FedDDServer, ProtocolConfig, run_scheme
from repro.core.allocation import (ClientTelemetry,
                                   solve_dropout_rates,
                                   solve_dropout_rates_overhead_aware)

pytestmark = pytest.mark.flcore

SPARSE_CODECS = ("bitmask", "index", "auto")


def _rand_mask(rng, c, density):
    m = (rng.random(c) < density).astype(np.float32)
    return m


# --------------------------------------------------------------- codecs

def test_mask_roundtrip_exact_and_length_matches_formula():
    rng = np.random.default_rng(0)
    for _ in range(25):
        c = int(rng.integers(1, 80))
        m = _rand_mask(rng, c, rng.random())
        for codec in SPARSE_CODECS:
            buf = codecs.encode_mask(m, codec)
            assert np.array_equal(codecs.decode_mask(buf, c, codec), m)
            formula = int(codecs._leaf_overhead(m[None], c, codec, np)[0])
            assert len(buf) == formula, (codec, c)


def test_mask_roundtrip_empty_and_full():
    for c in (1, 8, 9, 64, 65):
        for m in (np.zeros(c, np.float32), np.ones(c, np.float32)):
            for codec in SPARSE_CODECS:
                buf = codecs.encode_mask(m, codec)
                assert np.array_equal(codecs.decode_mask(buf, c, codec), m)


def test_traced_overhead_equals_numpy_overhead():
    rng = np.random.default_rng(1)
    m = (rng.random((6, 47)) < 0.3).astype(np.float32)
    for codec in SPARSE_CODECS:
        a = np.asarray(codecs._leaf_overhead(jnp.asarray(m), 47, codec, jnp))
        b = codecs._leaf_overhead(m, 47, codec, np)
        assert np.array_equal(a, b), codec


def test_varint_bytes_boundaries():
    vals = [0, 1, 127, 128, 16383, 16384, (1 << 21) - 1, 1 << 21]
    want = [1, 1, 1, 2, 2, 3, 3, 4]
    got_np = codecs.varint_bytes(np.asarray(vals), np)
    got_j = np.asarray(codecs.varint_bytes(jnp.asarray(vals), jnp))
    assert list(got_np) == want
    assert list(got_j) == want


def test_stacked_overhead_matches_per_client():
    rng = np.random.default_rng(2)
    masks = {"w": jnp.asarray(rng.random((5, 1, 20)) < 0.4, jnp.float32),
             "b": jnp.asarray(rng.random((5, 20)) < 0.4, jnp.float32)}
    params = {"w": jnp.zeros((5, 7, 20)), "b": jnp.zeros((5, 20))}
    for codec in SPARSE_CODECS:
        for qbits in (32, 8):
            comm = CommConfig(codec=codec, qbits=qbits)
            got = np.asarray(codecs.mask_overhead_bytes_stacked(
                masks, params, comm))
            for i in range(5):
                mi = jax.tree_util.tree_map(lambda l: l[i], masks)
                pi = jax.tree_util.tree_map(lambda l: l[i], params)
                assert got[i] == codecs.mask_overhead_bytes(mi, pi, comm)


# ------------------------------------------------------------- quantize

def test_payload_roundtrip_values():
    rng = np.random.default_rng(3)
    params = {"w": jnp.asarray(rng.normal(size=(6, 12)), jnp.float32),
              "b": jnp.asarray(rng.normal(size=(12,)), jnp.float32)}
    masks = {"w": jnp.asarray(rng.random(12) < 0.5,
                              jnp.float32).reshape(1, 12),
             "b": jnp.asarray(rng.random(12) < 0.5, jnp.float32)}
    key = quantize.client_quant_key(jax.random.PRNGKey(0), 7)
    for codec in ("dense",) + SPARSE_CODECS:
        for qbits in (32, 16, 8):
            comm = CommConfig(codec=codec, qbits=qbits)
            pl = payload.encode_upload(params, masks, comm, key)
            vals, mk = payload.decode_upload(pl)
            for v, m, p in zip(jax.tree_util.tree_leaves(vals),
                               jax.tree_util.tree_leaves(mk),
                               jax.tree_util.tree_leaves(params)):
                sel = np.broadcast_to(np.asarray(m), p.shape) > 0
                if qbits == 32:      # lossless: bit-identical
                    assert np.array_equal(v[sel], np.asarray(p)[sel])
                elif qbits == 16:    # deterministic cast roundtrip
                    ref = np.asarray(p, np.float16).astype(np.float32)
                    assert np.array_equal(v[sel], ref[sel])
                else:                # bounded, keyed-deterministic
                    scale = np.max(np.abs(np.asarray(p))) / 127.0
                    err = np.max(np.abs(v[sel] - np.asarray(p)[sel]))
                    assert err <= scale + 1e-7
            # nbytes equals the measured accounting
            oh = codecs.mask_overhead_bytes(masks, params, comm)
            kept = sum(int(np.sum(np.broadcast_to(np.asarray(m), p.shape)
                                  > 0))
                       for p, m in zip(jax.tree_util.tree_leaves(params),
                                       jax.tree_util.tree_leaves(masks)))
            assert pl.nbytes == oh + kept * quantize.value_bytes(qbits)


def test_int8_decode_matches_engine_qdq_and_is_deterministic():
    """The serialized int8 payload decodes to EXACTLY the values the
    in-engine quantize->dequantize feeds the aggregation, and re-encoding
    with the same key reproduces the same bytes (different key: not)."""
    rng = np.random.default_rng(4)
    params = {"w": jnp.asarray(rng.normal(size=(5, 9)), jnp.float32)}
    masks = {"w": jnp.asarray(rng.random(9) < 0.6,
                              jnp.float32).reshape(1, 9)}
    comm = CommConfig(codec="index", qbits=8)
    key = quantize.client_quant_key(jax.random.PRNGKey(3), 2)
    pl = payload.encode_upload(params, masks, comm, key)
    vals, mk = payload.decode_upload(pl)
    ref = quantize.quantize_dequantize(params, key, 8)
    sel = np.broadcast_to(np.asarray(masks["w"]), (5, 9)) > 0
    assert np.array_equal(vals["w"][sel], np.asarray(ref["w"])[sel])
    pl2 = payload.encode_upload(params, masks, comm, key)
    assert pl.leaves[0].value_bytes == pl2.leaves[0].value_bytes
    other = payload.encode_upload(
        params, masks, comm, quantize.client_quant_key(
            jax.random.PRNGKey(99), 2))
    assert pl.leaves[0].value_bytes != other.leaves[0].value_bytes


def test_stacked_qdq_matches_per_client_loop():
    rng = np.random.default_rng(5)
    x = {"w": jnp.asarray(rng.normal(size=(4, 6, 10)), jnp.float32),
         "b": jnp.asarray(rng.normal(size=(4, 10)), jnp.float32)}
    rk = jax.random.PRNGKey(11)
    for qbits in (16, 8):
        got = quantize.quantize_dequantize_stacked(x, rk, qbits)
        for i in range(4):
            xi = jax.tree_util.tree_map(lambda l: l[i], x)
            ref = quantize.quantize_dequantize(
                xi, quantize.client_quant_key(rk, i), qbits)
            for a, b in zip(jax.tree_util.tree_leaves(got),
                            jax.tree_util.tree_leaves(ref)):
                assert np.array_equal(np.asarray(a[i]), np.asarray(b))


# ----------------------------------------------- protocol: 4-path parity

def _client_params(key, n, scale=1.0):
    def one(k):
        k1, k2 = jax.random.split(k)
        return {
            "fc0": {"w": scale * jax.random.normal(k1, (20, 12)),
                    "b": jnp.zeros(12)},
            "fc1": {"w": scale * jax.random.normal(k2, (12, 5)),
                    "b": jnp.zeros(5)},
        }
    return [one(jax.random.fold_in(key, i)) for i in range(n)]


def _telemetry(n, nbytes, seed=0):
    rng = np.random.default_rng(seed)
    return ClientTelemetry(
        model_bytes=np.full(n, nbytes),
        uplink_rate=rng.uniform(1e3, 5e3, n),
        downlink_rate=rng.uniform(5e3, 2e4, n),
        compute_latency=rng.uniform(1.0, 5.0, n),
        num_samples=rng.integers(10, 50, n).astype(float),
        label_coverage=rng.uniform(0.5, 1.0, n),
        train_loss=np.ones(n))


def _fixture(n=6, seed=0):
    params = _client_params(jax.random.PRNGKey(seed), 1)[0]
    nbytes = float(sum(l.size * l.dtype.itemsize
                       for l in jax.tree_util.tree_leaves(params)))
    return params, _telemetry(n, nbytes, seed)


def _ltf(p, idx, key):
    return (jax.tree_util.tree_map(
        lambda x: x * 0.99 + 0.01 * jax.random.normal(key, x.shape), p),
        1.0 / (idx + 1.0))


def _trees_equal(a, b):
    return all(bool(jnp.all(x == y)) for x, y in zip(
        jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)))


@pytest.mark.parametrize("scheme", ["feddd", "fedavg", "fedcs", "oort"])
def test_default_comm_wire_equals_uploaded_all_paths(scheme):
    """dense codec + qbits=32: wire_bytes == uploaded_bytes bitwise and
    the learning state is bit-identical to a run with no comm config, on
    the loop, engine, and (via the comm-default ProtocolConfig) every
    routed path."""
    params, tel = _fixture()
    kw = dict(rounds=4, a_server=0.6, h=3, seed=0)
    for batched in (False, True):
        base = run_scheme(scheme, params, tel, _ltf, None,
                          batched=batched, **kw)
        comm = run_scheme(scheme, params, tel, _ltf, None, batched=batched,
                          comm=CommConfig(codec="dense", qbits=32), **kw)
        assert _trees_equal(base.global_params, comm.global_params)
        for rb, rc in zip(base.history, comm.history):
            assert rb.uploaded_fraction == rc.uploaded_fraction
            assert rc.wire_bytes == rc.uploaded_bytes     # the identity
            assert rb.uploaded_bytes == rc.uploaded_bytes
            assert rb.sim_time == rc.sim_time
            assert rb.mean_loss == rc.mean_loss


def test_default_comm_scanned_path_identity():
    """dense/32 on the multi-round scan: wire == uploaded bitwise and the
    stream matches the comm-less scanned stream."""
    params, tel = _fixture(n=8)

    @jax.jit
    def batched(stacked, key):
        new = jax.tree_util.tree_map(
            lambda x: x * 0.99 + 0.01 * jax.random.normal(
                jax.random.fold_in(key, 1), x.shape), stacked)
        l0 = jax.tree_util.tree_leaves(new)[0]
        return new, jnp.mean(jnp.abs(l0.reshape(l0.shape[0], -1)), axis=1)

    kw = dict(scheme="feddd", rounds=6, a_server=0.6, h=3, seed=0,
              allocator="jax", rounds_per_dispatch=3)
    r1 = FedDDServer(params, ProtocolConfig(**kw), tel).run(
        batched_train_fn=batched)
    r2 = FedDDServer(params, ProtocolConfig(comm=CommConfig(), **kw),
                     tel).run(batched_train_fn=batched)
    assert _trees_equal(r1.global_params, r2.global_params)
    for a, b in zip(r1.history, r2.history):
        assert a.uploaded_bytes == b.uploaded_bytes
        assert b.wire_bytes == b.uploaded_bytes
        assert a.sim_time == b.sim_time


def _overhead_of(rec, qbits):
    """The measured mask/scale overhead a record carries — an INTEGER
    byte count by construction, recovered exactly from the float fields
    (the value term inherits the loop-vs-engine density ulp, so totals
    are compared approx and overheads exactly)."""
    return round(rec.wire_bytes - rec.uploaded_bytes * (qbits / 32.0))


@pytest.mark.parametrize("codec", SPARSE_CODECS)
@pytest.mark.parametrize("qbits", [32, 16])
def test_sparse_codec_engine_matches_loop(codec, qbits):
    """Sparse codecs + lossless/cast values: the engine run reproduces
    the reference loop's measured overhead exactly (integer bytes) and
    the learning state bit for bit (fp16 casts are order-independent);
    byte totals agree to the pre-existing density-ulp tolerance."""
    params, tel = _fixture()
    kw = dict(rounds=4, a_server=0.6, h=3, seed=0,
              comm=CommConfig(codec=codec, qbits=qbits))
    loop = run_scheme("feddd", params, tel, _ltf, None, batched=False, **kw)
    eng = run_scheme("feddd", params, tel, _ltf, None, batched=True, **kw)
    assert _trees_equal(loop.global_params, eng.global_params)
    for rl, re_ in zip(loop.history, eng.history):
        assert _overhead_of(rl, qbits) == _overhead_of(re_, qbits) > 0
        assert rl.wire_bytes == pytest.approx(re_.wire_bytes, rel=1e-6)
        assert rl.uploaded_bytes == pytest.approx(re_.uploaded_bytes,
                                                  rel=1e-6)
        assert rl.sim_time == pytest.approx(re_.sim_time, rel=1e-9)
        assert rl.wire_bytes > rl.uploaded_bytes * (qbits / 32.0)


def test_int8_engine_matches_loop():
    """int8 stochastic rounding draws the same keyed noise on both paths
    (same fold discipline as masks): identical quantization decisions and
    wire overheads.  The QDQ barriers pin every JITTED rendering to the
    same bits (per-round engine == grouped == scanned — the other tests);
    the EAGER reference loop's per-op dispatch may legally round the
    division chain an ulp apart (XLA compiles per program), so params are
    held to ulp scale here, not bitwise."""
    params, tel = _fixture()
    kw = dict(rounds=3, a_server=0.6, h=2, seed=0,
              comm=CommConfig(codec="bitmask", qbits=8))
    loop = run_scheme("feddd", params, tel, _ltf, None, batched=False, **kw)
    eng = run_scheme("feddd", params, tel, _ltf, None, batched=True, **kw)
    for a, b in zip(jax.tree_util.tree_leaves(loop.global_params),
                    jax.tree_util.tree_leaves(eng.global_params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=0, atol=1e-6)
    for rl, re_ in zip(loop.history, eng.history):
        assert _overhead_of(rl, 8) == _overhead_of(re_, 8) > 0
        assert rl.wire_bytes == pytest.approx(re_.wire_bytes, rel=1e-6)
        assert rl.mean_loss == re_.mean_loss


def test_sparse_codec_scanned_wire_matches_per_round():
    """The scanned path's wire-byte telemetry equals per-round engine
    dispatch exactly (int32 overheads in the trace), and the learning
    state matches bit for bit."""
    params, tel = _fixture(n=8)

    @jax.jit
    def batched(stacked, key):
        new = jax.tree_util.tree_map(
            lambda x: x * 0.99 + 0.01 * jax.random.normal(
                jax.random.fold_in(key, 1), x.shape), stacked)
        l0 = jax.tree_util.tree_leaves(new)[0]
        return new, jnp.mean(jnp.abs(l0.reshape(l0.shape[0], -1)), axis=1)

    kw = dict(scheme="feddd", rounds=6, a_server=0.6, h=3, seed=0,
              allocator="jax", comm=CommConfig(codec="index", qbits=16))
    seq = FedDDServer(params, ProtocolConfig(**kw), tel).run(
        batched_train_fn=batched)
    scan = FedDDServer(params, ProtocolConfig(rounds_per_dispatch=3, **kw),
                       tel).run(batched_train_fn=batched)
    assert _trees_equal(seq.global_params, scan.global_params)
    for a, b in zip(seq.history, scan.history):
        assert a.wire_bytes == b.wire_bytes
        assert a.uploaded_bytes == b.uploaded_bytes
        assert b.sim_time == pytest.approx(a.sim_time, rel=1e-9)


def test_sparse_codec_grouped_matches_loop():
    """Ragged fleet: grouped engine wire accounting equals the reference
    loop (per-leaf overheads computed at native widths)."""
    n = 5
    full = _client_params(jax.random.PRNGKey(0), 1)[0]

    def slice_w(p, frac):
        def s(l):
            if l.ndim == 0:
                return l
            w = max(1, int(l.shape[-1] * frac))
            return l[..., :w]
        return jax.tree_util.tree_map(s, p)

    clients = [full, slice_w(full, 0.6), full, slice_w(full, 0.6),
               slice_w(full, 0.8)]
    nbytes = [float(sum(l.size * l.dtype.itemsize
                        for l in jax.tree_util.tree_leaves(p)))
              for p in clients]
    tel = dataclasses.replace(_telemetry(n, 1.0),
                              model_bytes=np.asarray(nbytes))
    kw = dict(rounds=3, a_server=0.6, h=2, seed=0,
              comm=CommConfig(codec="index", qbits=32))
    loop = run_scheme("feddd", full, tel, _ltf, None, batched=False,
                      client_params=clients, **kw)
    grp = run_scheme("feddd", full, tel, _ltf, None, batched=True,
                     client_params=clients, **kw)
    assert _trees_equal(loop.global_params, grp.global_params)
    for rl, rg in zip(loop.history, grp.history):
        assert _overhead_of(rl, 32) == _overhead_of(rg, 32) > 0
        assert rl.wire_bytes == pytest.approx(rg.wire_bytes, rel=1e-6)
        assert rl.uploaded_bytes == pytest.approx(rg.uploaded_bytes,
                                                  rel=1e-6)


def test_dense_mask_uploads_charge_true_width_overhead():
    """Baseline (all-ones-mask) uploads carry a collapsed channel dim in
    the engines; their recorded overhead must be the closed-form
    full-upload constant at TRUE widths — identical to encoding a
    materialized all-ones mask AND to the analytic model the clock
    charges at dropout 0, on the loop and the engine alike."""
    params, tel = _fixture()
    comm = CommConfig(codec="bitmask", qbits=16)
    spec = WireSpec.from_params(params)
    const = codecs.full_upload_overhead_bytes(spec, comm)
    # equals the measured overhead of real all-ones masks...
    ones = jax.tree_util.tree_map(
        lambda l: jnp.ones((1,) * (l.ndim - 1) + (l.shape[-1],)), params)
    assert const == codecs.mask_overhead_bytes(ones, params, comm)
    # ...and the analytic model's overhead at dropout 0
    analytic = float(payload.analytic_wire_bytes(spec, 0.0, comm))
    values = spec.total_elements * quantize.value_bytes(16)
    assert const == round(analytic - values)
    n = tel.num_clients
    kw = dict(rounds=2, a_server=0.6, h=2, seed=0, comm=comm)
    for batched in (False, True):
        res = run_scheme("fedavg", params, tel, _ltf, None,
                         batched=batched, **kw)
        for r in res.history:
            assert _overhead_of(r, 16) == const * n, batched


def test_payload_roundtrip_square_leaf_channel_axis_0():
    """Square leaves are shape-ambiguous: the payload must carry the
    channel axis so a channel_axis=0 mask decodes onto axis 0."""
    rng = np.random.default_rng(9)
    c = 7
    params = {"w": jnp.asarray(rng.normal(size=(c, c)), jnp.float32)}
    m1d = (rng.random(c) < 0.5).astype(np.float32)
    masks = {"w": jnp.asarray(m1d).reshape(c, 1)}     # channel axis 0
    comm = CommConfig(codec="index", qbits=32)
    vals, mk = payload.decode_upload(
        payload.encode_upload(params, masks, comm, None))
    ref = np.broadcast_to(m1d.reshape(c, 1), (c, c)) > 0
    assert np.array_equal(mk["w"] > 0, ref)
    assert np.array_equal(vals["w"][ref], np.asarray(params["w"])[ref])


# ------------------------------------------------- degenerate settings

def test_zero_density_upload_charges_header_only_bytes():
    """A mask that keeps nothing ships no values — only the per-leaf
    framing (header + bitmask bits for 'bitmask'; header alone for
    'index'), and no int8 scale."""
    masks = {"w": jnp.zeros((3, 1, 16)), "b": jnp.zeros((3, 16))}
    params = {"w": jnp.zeros((3, 4, 16)), "b": jnp.zeros((3, 16))}
    bm = np.asarray(codecs.mask_overhead_bytes_stacked(
        masks, params, CommConfig(codec="bitmask", qbits=8)))
    ix = np.asarray(codecs.mask_overhead_bytes_stacked(
        masks, params, CommConfig(codec="index", qbits=8)))
    per_leaf_bm = codecs.HEADER_BYTES + codecs.bitmask_bytes(16)
    assert np.all(bm == 2 * per_leaf_bm)          # no scale bytes: kept==0
    assert np.all(ix == 2 * codecs.HEADER_BYTES)  # header-only
    # and the wire accounting is exactly that overhead (zero value bytes)
    up, wire = account_uplink(np.zeros(3), np.ones(3, bool),
                              np.full(3, 4096.0), ix,
                              CommConfig(codec="index", qbits=8))
    assert up == 0.0
    assert wire == float(2 * codecs.HEADER_BYTES * 3)


def test_full_density_dense_fallback_beats_index():
    """At density 1 the dense (values-only) codec is strictly cheaper
    than index coding — the crossover's upper end."""
    spec = WireSpec(((64, 64 * 32), (64, 64)))
    dense = float(payload.analytic_wire_bytes(spec, 0.0, CommConfig()))
    index = float(payload.analytic_wire_bytes(
        spec, 0.0, CommConfig(codec="index")))
    bitmask = float(payload.analytic_wire_bytes(
        spec, 0.0, CommConfig(codec="bitmask")))
    assert dense < bitmask < index


def test_bitmask_index_crossover_density():
    """Index coding wins at low density, bitmask at high density, with
    the crossover near density 1/8 (1 varint byte per kept channel vs
    C/8 bitmask bytes)."""
    c = 512
    m_low = np.zeros(c, np.float32)
    m_low[:: c // 16] = 1.0          # density 1/32
    m_high = np.ones(c, np.float32)
    m_high[:: c // 16] = 0.0         # density 31/32
    ix_low = int(codecs._leaf_overhead(m_low[None], c, "index", np)[0])
    ix_high = int(codecs._leaf_overhead(m_high[None], c, "index", np)[0])
    bm = int(codecs._leaf_overhead(m_low[None], c, "bitmask", np)[0])
    assert ix_low < bm < ix_high
    # analytic model places the crossover in (1/16, 1/4) around ~1/8
    spec = WireSpec(((c, c),))
    dens_grid = np.linspace(0.01, 0.99, 197)
    ix = np.asarray([float(payload.analytic_wire_bytes(
        spec, 1.0 - d, CommConfig(codec="index"))) for d in dens_grid])
    bmv = np.asarray([float(payload.analytic_wire_bytes(
        spec, 1.0 - d, CommConfig(codec="bitmask"))) for d in dens_grid])
    cross = dens_grid[np.argmax(ix > bmv)]
    assert 1 / 16 < cross < 1 / 4


def test_dead_uplink_client_cut_by_deadline_under_codec_bytes():
    """Deadline policy + codec-measured bytes: a client whose uplink is
    effectively dead never lands its (sparse-encoded) upload; the round
    aggregates without it and the wire accounting reflects the arrivals
    only."""
    from repro.sim import SimConfig

    params, tel = _fixture(n=6, seed=1)
    dead = dataclasses.replace(
        tel, uplink_rate=np.concatenate([[1e-6], tel.uplink_rate[1:]]))
    res = run_scheme("feddd", params, dead, _ltf, None,
                     sim=SimConfig(policy="deadline"), rounds=3,
                     a_server=0.6, h=2, seed=0,
                     comm=CommConfig(codec="index", qbits=16))
    n = dead.num_clients
    assert all(r.participants < n for r in res.history)
    for r in res.history:
        assert 0.0 < r.wire_bytes
        # fp16 values: the wire carries about half the raw bytes plus
        # positive mask overhead — never the full-fleet dense mass
        assert r.wire_bytes < float(np.sum(dead.model_bytes))
        assert r.wire_bytes > r.uploaded_bytes * 0.5


def test_sim_sync_static_matches_protocol_with_codec():
    """The sim's sync+static fidelity contract extends to non-default
    wire formats: identical wire_bytes and Eq. (12) times."""
    params, tel = _fixture(n=5, seed=2)
    kw = dict(rounds=3, a_server=0.6, h=2, seed=0,
              comm=CommConfig(codec="bitmask", qbits=16))
    proto = run_scheme("feddd", params, tel, _ltf, None, **kw)
    sim = run_scheme("feddd", params, tel, _ltf, None, sim=True, **kw)
    assert _trees_equal(proto.global_params, sim.global_params)
    for rp, rs in zip(proto.history, sim.history):
        assert rp.wire_bytes == rs.wire_bytes
        assert rp.sim_time == pytest.approx(rs.sim_time, rel=1e-12)


# --------------------------------------------- overhead-aware allocation

def test_overhead_aware_allocation_binds_on_wire_bytes():
    """The overhead-aware LP meets the A_server budget measured in
    ON-WIRE bytes; the linear proxy overshoots it when the codec has a
    density-independent floor."""
    n = 8
    rng = np.random.default_rng(7)
    nbytes = 4096.0
    tel = ClientTelemetry(
        model_bytes=np.full(n, nbytes),
        uplink_rate=rng.uniform(1e3, 5e3, n),
        downlink_rate=rng.uniform(5e3, 2e4, n),
        compute_latency=rng.uniform(1.0, 5.0, n),
        num_samples=rng.integers(10, 50, n).astype(float),
        label_coverage=rng.uniform(0.5, 1.0, n),
        train_loss=rng.uniform(0.5, 2.0, n))
    spec = WireSpec(((32, 512), (32, 512)))
    specs = [spec] * n
    comm = CommConfig(codec="bitmask", qbits=8,
                      overhead_aware_allocation=True)
    kw = dict(a_server=0.6, d_max=0.8, delta=1.0, global_model_bytes=nbytes)
    aware = solve_dropout_rates_overhead_aware(tel, specs, comm=comm, **kw)
    assert aware.feasible
    wire = payload.analytic_uplink_vector(specs, aware.dropout_rates, comm)
    full = payload.analytic_uplink_vector(specs, np.zeros(n), comm)
    target = 0.6 * float(np.sum(full))
    assert float(np.sum(wire)) == pytest.approx(target, rel=5e-2)
    # the linear proxy, charged on the same wire model, spends MORE bytes
    linear = solve_dropout_rates(tel, **kw)
    wire_lin = payload.analytic_uplink_vector(specs, linear.dropout_rates,
                                              comm)
    assert float(np.sum(wire_lin)) > float(np.sum(wire))


def test_overhead_aware_requires_numpy_allocator():
    with pytest.raises(ValueError, match="overhead_aware"):
        ProtocolConfig(
            allocator="jax",
            comm=CommConfig(codec="index", overhead_aware_allocation=True))


def test_comm_config_validation():
    with pytest.raises(ValueError, match="codec"):
        CommConfig(codec="huffman")
    with pytest.raises(ValueError, match="qbits"):
        CommConfig(qbits=4)


def test_overhead_aware_end_to_end_run():
    """A protocol run with overhead-aware allocation completes and keeps
    its measured wire bytes near the budget once rates adapt."""
    params, tel = _fixture(n=6, seed=3)
    res = run_scheme(
        "feddd", params, tel, _ltf, None, rounds=4, a_server=0.6, h=10,
        seed=0, comm=CommConfig(codec="bitmask", qbits=8,
                                overhead_aware_allocation=True))
    full_wire = float(np.sum(payload.analytic_uplink_vector(
        [WireSpec.from_params(params)] * tel.num_clients,
        np.zeros(tel.num_clients),
        CommConfig(codec="bitmask", qbits=8))))
    # rounds after the first allocation should track the wire budget
    for r in res.history[2:]:
        assert r.wire_bytes == pytest.approx(0.6 * full_wire, rel=0.15)
