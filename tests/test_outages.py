"""Correlated cell-outage layer (repro/sim/outages.py): inert-config
transparency, chain determinism, overlay semantics, and the survivor-only
allocation re-solve.

Pins the survivability contracts:

* inert configs — ``cells=0`` / ``p_out=0`` make the overlay a pure
  pass-through: ``round_faults`` returns the inner model's draw
  bit-identically and a zero-config model leaves a full simulator run
  BIT-IDENTICAL to the fault-free one;
* determinism — the Gilbert–Elliott chain is a pure function of
  (seed, epoch): query order, prior queries, and process boundaries
  cannot change it, and epoch 0 is always all-up;
* overlay semantics — every member of a down cell crashes with a
  per-(epoch, client) keyed crash fraction, overriding whatever the
  inner draw said (retries/corruption zeroed);
* incidents — up->down / down->up transitions surface as
  ``outage_begin`` / ``outage_end`` events (cell, members, duration)
  through :func:`repro.sim.faults.incident_events`;
* end-to-end — a sim run under the overlay loses exactly the downed
  cells each round and the post-round LP re-solve holds the downed
  clients' dropout rates instead of consuming budget from stale rows.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.allocation import ClientTelemetry
from repro.sim import (CellOutageModel, FaultConfig, OutageConfig,
                       RandomFaults, ScriptedFaults, SimConfig, run_sim)
from repro.sim.faults import RoundFaults, incident_events
from repro.sim.outages import _TAG_OUTAGE

pytestmark = pytest.mark.flcore


# --- shared fixtures ---------------------------------------------------------

def _params(key):
    k1, k2 = jax.random.split(key)
    return {
        "fc0": {"w": jax.random.normal(k1, (20, 12)), "b": jnp.zeros(12)},
        "fc1": {"w": jax.random.normal(k2, (12, 5)), "b": jnp.zeros(5)},
    }


def _tel(n, seed=0):
    rng = np.random.default_rng(seed)
    nbytes = float(sum(l.size * l.dtype.itemsize
                       for l in jax.tree_util.tree_leaves(
                           _params(jax.random.PRNGKey(0)))))
    return ClientTelemetry(
        model_bytes=np.full(n, nbytes),
        uplink_rate=rng.uniform(1e3, 5e3, n),
        downlink_rate=rng.uniform(5e3, 2e4, n),
        compute_latency=rng.uniform(1.0, 5.0, n),
        num_samples=rng.integers(10, 50, n).astype(float),
        label_coverage=rng.uniform(0.5, 1.0, n),
        train_loss=np.ones(n))


def _ltf(p, idx, key):
    return (jax.tree_util.tree_map(
        lambda x: x * 0.99 + 0.01 * jax.random.normal(key, x.shape), p),
        1.0 / (idx + 1.0))


def _trees_equal(a, b):
    return all(bool(jnp.all(x == y)) for x, y in zip(
        jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)))


def _faults_equal(a: RoundFaults, b: RoundFaults) -> bool:
    return all(np.array_equal(getattr(a, f), getattr(b, f))
               for f in ("crashed", "crash_frac", "aborted", "retries",
                         "extra_bytes", "extra_delay", "sent_bytes",
                         "corrupt"))


# --- config / assignment ------------------------------------------------------

def test_outage_config_validates():
    with pytest.raises(ValueError, match="cells"):
        OutageConfig(cells=-1)
    with pytest.raises(ValueError, match="p_out"):
        OutageConfig(cells=2, p_out=1.5)
    with pytest.raises(ValueError, match="p_back"):
        OutageConfig(cells=2, p_back=-0.1)


def test_round_robin_assignment_and_members():
    m = CellOutageModel(7, OutageConfig(cells=3, p_out=0.2))
    np.testing.assert_array_equal(m.assignment, np.arange(7) % 3)
    np.testing.assert_array_equal(m.cell_members(0), [0, 3, 6])
    np.testing.assert_array_equal(m.cell_members(2), [2, 5])


def test_explicit_assignment_validated():
    ok = CellOutageModel(4, OutageConfig(cells=2, p_out=0.2),
                         assignment=[1, 1, 0, 0])
    np.testing.assert_array_equal(ok.cell_members(1), [0, 1])
    with pytest.raises(ValueError, match="one cell index per client"):
        CellOutageModel(4, OutageConfig(cells=2, p_out=0.2),
                        assignment=[0, 1])
    with pytest.raises(ValueError, match=r"in \[0,2\)"):
        CellOutageModel(4, OutageConfig(cells=2, p_out=0.2),
                        assignment=[0, 1, 2, 0])


# --- inert configs ------------------------------------------------------------

@pytest.mark.parametrize("cfg", [
    OutageConfig(),                                # cells=0
    OutageConfig(cells=3, p_out=0.0),              # chain can never fire
])
def test_inert_overlay_is_pure_passthrough(cfg):
    n = 5
    inner = RandomFaults(FaultConfig(crash_rate=0.3, loss_rate=0.2,
                                     corrupt_rate=0.2, seed=7))
    overlay = CellOutageModel(n, cfg, inner=inner)
    assert not overlay.active
    wire = np.full(n, 5e4)
    rate = np.full(n, 2e3)
    for epoch in (0, 1, 5):
        assert overlay.outage_mask(epoch) is None
        assert _faults_equal(overlay.round_faults(epoch, wire, rate),
                             inner.round_faults(epoch, wire, rate))
    # overlay inherits the inner model's config (quorum, budget, ...)
    assert overlay.config is inner.config
    assert overlay.may_corrupt


def test_inert_overlay_without_inner_is_clean():
    n = 4
    m = CellOutageModel(n, OutageConfig())
    fr = m.round_faults(3, np.full(n, 1e4), np.full(n, 1e3))
    assert _faults_equal(fr, RoundFaults.clean(n))
    assert not m.may_corrupt


def test_zero_config_outage_run_bit_identical_to_fault_free():
    """The acceptance contract: a zero-rate CellOutageModel routed
    through the simulator leaves the run BIT-IDENTICAL to no faults."""
    n = 5
    params = _params(jax.random.PRNGKey(0))
    tel = _tel(n)
    kw = dict(rounds=3, a_server=0.6, h=3, seed=0,
              sim=SimConfig(policy="sync"))
    ref = run_sim("feddd", params, tel, _ltf, None, **kw)
    got = run_sim("feddd", params, tel, _ltf, None,
                  faults=CellOutageModel(n, OutageConfig()), **kw)
    assert ref.event_trace == got.event_trace
    for rr, rg in zip(ref.history, got.history):
        assert rr.sim_time == rg.sim_time
        assert rr.wire_bytes == rg.wire_bytes
        np.testing.assert_array_equal(rr.dropout_rates, rg.dropout_rates)
    assert _trees_equal(ref.global_params, got.global_params)


# --- chain determinism --------------------------------------------------------

def test_chain_deterministic_and_query_order_independent():
    cfg = OutageConfig(cells=4, p_out=0.4, p_back=0.3, seed=11)
    seq = CellOutageModel(10, cfg)
    jump = CellOutageModel(10, cfg)
    states = [seq.down_cells(e) for e in range(8)]
    # jump straight to epoch 7, then read scattered epochs
    np.testing.assert_array_equal(jump.down_cells(7), states[7])
    for e in (3, 0, 5, 1):
        np.testing.assert_array_equal(jump.down_cells(e), states[e])
    # epoch 0 is all-up by construction
    assert not states[0].any()
    assert seq._transitions(0) == []


def test_outage_crash_fracs_keyed_per_epoch_and_client():
    """Each outaged member's crash fraction is a pure function of
    (outage seed, epoch, client) — replayable without persisting state."""
    n = 4
    cfg = OutageConfig(cells=1, p_out=1.0, p_back=0.0, seed=5)
    m = CellOutageModel(n, cfg)
    fr = m.round_faults(2, np.full(n, 1e4), np.full(n, 1e3))
    assert fr.crashed.all()
    for i in range(n):
        want = np.random.default_rng(
            (cfg.seed, _TAG_OUTAGE, 2, i)).uniform()
        assert fr.crash_frac[i] == want


# --- overlay semantics --------------------------------------------------------

def test_overlay_overrides_inner_draw_for_downed_members():
    """A client inside a down cell crashes even when the inner draw had
    it surviving with retries or shipping a corrupted payload."""
    n = 4
    inner = ScriptedFaults(chunk_retries={(1, 0): 3},
                           corrupt={(1, 1): "nan"})
    m = CellOutageModel(n, OutageConfig(cells=2, p_out=1.0, p_back=0.0),
                        inner=inner, assignment=[0, 0, 1, 1])
    wire, rate = np.full(n, 1e4), np.full(n, 1e3)
    base = inner.round_faults(1, wire, rate)
    assert base.retries[0] == 3 and base.corrupt[1] > 0
    fr = m.round_faults(1, wire, rate)          # both cells down
    assert fr.crashed.all()
    np.testing.assert_array_equal(fr.retries, np.zeros(n, int))
    np.testing.assert_array_equal(fr.corrupt, np.zeros(n, int))
    np.testing.assert_array_equal(fr.extra_bytes, np.zeros(n))
    np.testing.assert_array_equal(fr.sent_bytes, np.zeros(n))


def test_outage_mask_maps_cells_through_assignment():
    n = 6
    m = CellOutageModel(n, OutageConfig(cells=2, p_out=1.0, p_back=0.0),
                        assignment=[0, 1, 0, 1, 0, 1])
    assert m.outage_mask(0) is not None         # active overlay
    assert not m.outage_mask(0).any()           # ... but epoch 0 all-up
    mask = m.outage_mask(1)
    assert mask.all()                           # p_out=1: every cell down
    down = m.down_cells(1)
    np.testing.assert_array_equal(mask, down[m.assignment])


def test_transitions_and_incident_events():
    """p_out=1, p_back=1 alternates every cell down/up each epoch:
    epoch 1 emits outage_begin, epoch 2 outage_end with duration 1, and
    incident_events forwards both fleet-scoped (unfiltered by the
    schedule)."""
    n = 4
    m = CellOutageModel(n, OutageConfig(cells=2, p_out=1.0, p_back=1.0))
    wire, rate = np.full(n, 1e4), np.full(n, 1e3)
    fr1 = m.round_faults(1, wire, rate)
    begins = [ev for ev in fr1.outages if ev["kind"] == "outage_begin"]
    assert sorted(ev["cell"] for ev in begins) == [0, 1]
    assert begins[0]["members"] == [int(i) for i in
                                    m.cell_members(begins[0]["cell"])]
    fr2 = m.round_faults(2, wire, rate)
    assert not fr2.crashed.any()                # everything back up
    ends = [ev for ev in fr2.outages if ev["kind"] == "outage_end"]
    assert sorted(ev["cell"] for ev in ends) == [0, 1]
    assert all(ev["duration"] == 1 for ev in ends)
    # incident_events forwards outages even for unscheduled clients
    events = incident_events(fr2, np.zeros(n, bool))
    assert [ev["kind"] for ev in events] == ["outage_end", "outage_end"]


# --- end-to-end through the simulator -----------------------------------------

def test_sim_run_loses_exactly_the_downed_cells():
    """Survivors per round == fleet minus the members of the cells the
    chain has down at that round's epoch, and the post-round LP re-solve
    HOLDS the downed clients' dropout rates (survivor-only telemetry)."""
    n, cells = 6, 3
    cfg = OutageConfig(cells=cells, p_out=0.6, p_back=0.4, seed=9)
    params = _params(jax.random.PRNGKey(0))
    tel = _tel(n)
    res = run_sim("feddd", params, tel, _ltf, None,
                  sim=SimConfig(policy="sync"),
                  faults=CellOutageModel(n, cfg),
                  rounds=5, a_server=0.6, h=3, seed=0)
    oracle = CellOutageModel(n, cfg)            # fresh chain, same draw
    saw_outage = False
    for rec in res.history:
        mask = oracle.outage_mask(rec.round - 1)
        expect = n - int(mask.sum())
        assert rec.survivors == expect
        if 0 < int(mask.sum()) < n:
            saw_outage = True
            prev_d = (res.history[rec.round - 2].dropout_rates
                      if rec.round >= 2 else np.zeros(n))
            np.testing.assert_array_equal(
                rec.dropout_rates[mask], np.asarray(prev_d)[mask])
    assert saw_outage, "seed 9 scenario regressed — no partial outage"


def test_outage_incidents_reach_the_run_log(tmp_path):
    """outage_begin / outage_end flow through the obs layer as JSONL
    fault events."""
    import json
    from repro.obs import ObsConfig
    n = 4
    params = _params(jax.random.PRNGKey(0))
    tel = _tel(n)
    path = tmp_path / "run.jsonl"
    run_sim("feddd", params, tel, _ltf, None,
            sim=SimConfig(policy="sync"),
            faults=CellOutageModel(
                n, OutageConfig(cells=2, p_out=1.0, p_back=1.0)),
            obs=ObsConfig(enabled=True, jsonl_path=str(path)),
            rounds=3, a_server=0.6, h=3, seed=0)
    kinds = [json.loads(line).get("kind")
             for line in path.read_text().splitlines()
             if json.loads(line).get("event") == "fault"]
    assert "outage_begin" in kinds
    assert "outage_end" in kinds
