"""Eq. (4) sparse collective primitives vs a dense-allreduce oracle.

The client-sharded engines reduce Eq. (4) (num, den) partials over the
mesh's ``clients`` axis through ``core/sparse_collective.py``.  These
tests pin the primitives standalone: compaction/scatter round trips on a
single device, and the compacted cross-shard reduction against the dense
``lax.psum`` oracle — including ragged ``k_local`` per shard (differential
dropout riding the SPMD-static buffer) and the overflow certificate.

Multi-device cases run in a subprocess with
``--xla_force_host_platform_device_count`` so the main pytest process
keeps a single device (conftest policy)."""

import subprocess
import sys
import textwrap
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.sparse_collective import (compact_topk,
                                          make_federated_numden_allreduce,
                                          scatter_accumulate)

pytestmark = pytest.mark.flcore

SRC = str(Path(__file__).resolve().parents[1] / "src")


def _run_sub(code: str, devices: int = 4) -> str:
    env = {"XLA_FLAGS": f"--xla_force_host_platform_device_count={devices}",
           "PYTHONPATH": SRC, "PATH": "/usr/bin:/bin"}
    import os
    env.update({k: v for k, v in os.environ.items()
                if k not in env and k != "XLA_FLAGS"})
    res = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=300)
    assert res.returncode == 0, res.stderr[-3000:]
    return res.stdout


# --------------------------------------------------- single-device units

def test_compact_topk_selects_by_score():
    vals = jnp.arange(24.0).reshape(6, 4)
    scores = jnp.asarray([0.1, 5.0, 0.0, 3.0, 4.0, 0.2])
    compact, idx = compact_topk(vals, scores, 3)
    assert sorted(np.asarray(idx).tolist()) == [1, 3, 4]
    for row, i in zip(np.asarray(compact), np.asarray(idx)):
        np.testing.assert_array_equal(row, np.asarray(vals)[i])


def test_scatter_accumulate_roundtrips_compaction():
    rng = np.random.default_rng(0)
    dense = jnp.asarray(rng.normal(size=(8, 5)), jnp.float32)
    scores = jnp.asarray(rng.uniform(1.0, 2.0, 8), jnp.float32)
    compact, idx = compact_topk(dense, scores, 8)
    num, cnt = scatter_accumulate(dense.shape, compact, idx, 2.0)
    np.testing.assert_allclose(np.asarray(num), 2.0 * np.asarray(dense),
                               rtol=1e-6)
    np.testing.assert_allclose(np.asarray(cnt), np.full(8, 2.0))


def test_scatter_accumulate_adds_duplicate_indices():
    compact = jnp.ones((3, 2), jnp.float32)
    idx = jnp.asarray([1, 1, 2], jnp.int32)
    num, cnt = scatter_accumulate((4, 2), compact, idx,
                                  jnp.asarray([1.0, 2.0, 4.0]))
    np.testing.assert_allclose(np.asarray(cnt), [0.0, 3.0, 4.0, 0.0])
    np.testing.assert_allclose(np.asarray(num)[1], [3.0, 3.0])


def test_make_federated_numden_rejects_bad_fraction():
    with pytest.raises(ValueError):
        make_federated_numden_allreduce(0.0, "clients")
    with pytest.raises(ValueError):
        make_federated_numden_allreduce(1.5, "clients")


# --------------------------------------- multi-device vs the dense oracle

_ORACLE_PRELUDE = """
import numpy as np
import jax, jax.numpy as jnp
from jax import lax
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P
from repro.core.sparse_collective import (
    make_federated_numden_allreduce, sparse_numden_allreduce)

P_DEV = jax.device_count()
mesh = Mesh(np.asarray(jax.devices()), ("clients",))
rng = np.random.default_rng(7)
C, F = 8, 5

def shard_reduce(fn, num, den):
    wrapped = shard_map(fn, mesh,
                        in_specs=(P("clients"), P("clients")),
                        out_specs=(P(), P(), P()),
                        check_rep=False)
    return wrapped(num, den)

def dense_oracle(num, den):
    return (np.sum(np.asarray(num, np.float64), axis=0).astype(np.float32),
            np.sum(np.asarray(den, np.float64), axis=0).astype(np.float32))
"""


def test_sparse_numden_matches_dense_oracle_when_lossless():
    """Every shard's nonzero channels fit the buffer -> exact mass,
    overflow == 0, for uniform and RAGGED per-shard sparsity."""
    code = _ORACLE_PRELUDE + """
# each shard keeps <= 3 of 8 channels; buffer k=4 -> lossless
num = np.zeros((P_DEV, C, F), np.float32)
den = np.zeros((P_DEV, C), np.float32)
for s in range(P_DEV):
    keep = rng.choice(C, size=rng.integers(1, 4), replace=False)
    den[s, keep] = rng.uniform(0.5, 2.0, keep.size)
    num[s, keep] = rng.normal(size=(keep.size, F)) * den[s, keep][:, None]

def body(n, d):
    return sparse_numden_allreduce(n[0], d[0], 4, "clients")

num_tot, den_tot, overflow = shard_reduce(body, jnp.asarray(num),
                                          jnp.asarray(den))
on, od = dense_oracle(num, den)
assert float(overflow) == 0.0, float(overflow)
np.testing.assert_allclose(np.asarray(num_tot), on, rtol=1e-5, atol=1e-6)
np.testing.assert_allclose(np.asarray(den_tot), od, rtol=1e-5, atol=1e-6)
print("OK")
"""
    assert "OK" in _run_sub(code)


def test_sparse_numden_overflow_certifies_lossy_compaction():
    """More nonzero channels than the buffer: overflow counts exactly the
    channels that did not fit, and the reduced mass really differs."""
    code = _ORACLE_PRELUDE + """
num = np.zeros((P_DEV, C, F), np.float32)
den = np.ones((P_DEV, C), np.float32)          # all C channels nonzero
num[:] = rng.normal(size=num.shape)

def body(n, d):
    return sparse_numden_allreduce(n[0], d[0], 3, "clients")

num_tot, den_tot, overflow = shard_reduce(body, jnp.asarray(num),
                                          jnp.asarray(den))
# every shard overflows by C - k = 5 channels
assert float(overflow) == P_DEV * (C - 3), float(overflow)
on, od = dense_oracle(num, den)
assert not np.allclose(np.asarray(den_tot), od)
print("OK")
"""
    assert "OK" in _run_sub(code)


def test_ragged_k_local_zeroes_rows_beyond_each_shards_allocation():
    """Differential dropout on the static buffer: shard s keeps only its
    own k_local(s) <= k rows; the oracle masks the same rows host-side."""
    code = _ORACLE_PRELUDE + """
K = 4
num = rng.normal(size=(P_DEV, C, F)).astype(np.float32)
den = rng.uniform(0.5, 2.0, size=(P_DEV, C)).astype(np.float32)
k_locals = np.asarray([1 + (s % K) for s in range(P_DEV)], np.int32)

def body(n, d):
    idx = lax.axis_index("clients")
    return sparse_numden_allreduce(n[0], d[0], K, "clients",
                                   k_local=jnp.asarray(k_locals)[idx])

num_tot, den_tot, overflow = shard_reduce(body, jnp.asarray(num),
                                          jnp.asarray(den))

# host oracle: per shard, keep only the top-k_local channels by den
on = np.zeros((C, F), np.float64)
od = np.zeros((C,), np.float64)
for s in range(P_DEV):
    order = np.argsort(-den[s], kind="stable")
    keep = order[: k_locals[s]]
    on[keep] += num[s, keep]
    od[keep] += den[s, keep]
np.testing.assert_allclose(np.asarray(num_tot), on.astype(np.float32),
                           rtol=1e-5, atol=1e-6)
np.testing.assert_allclose(np.asarray(den_tot), od.astype(np.float32),
                           rtol=1e-5, atol=1e-6)
print("OK")
"""
    assert "OK" in _run_sub(code)


def test_keep_fraction_one_routes_to_dense_psum():
    """make_federated_numden_allreduce(1.0) must equal the oracle exactly
    on every channel (dense psum, no compaction, zero overflow)."""
    code = _ORACLE_PRELUDE + """
num = rng.normal(size=(P_DEV, C, F)).astype(np.float32)
den = rng.uniform(0.0, 2.0, size=(P_DEV, C)).astype(np.float32)
f = make_federated_numden_allreduce(1.0, "clients")

def body(n, d):
    return f(n[0], d[0])

num_tot, den_tot, overflow = shard_reduce(body, jnp.asarray(num),
                                          jnp.asarray(den))
on, od = dense_oracle(num, den)
assert float(overflow) == 0.0
np.testing.assert_allclose(np.asarray(num_tot), on, rtol=1e-6, atol=1e-6)
np.testing.assert_allclose(np.asarray(den_tot), od, rtol=1e-6, atol=1e-6)
print("OK")
"""
    assert "OK" in _run_sub(code)


def test_fractional_buffer_sizing_matches_ceil():
    """keep_fraction < 1 sizes the static buffer at ceil(C * fraction),
    floored at one channel."""
    code = _ORACLE_PRELUDE + """
f = make_federated_numden_allreduce(0.5, "clients")
num = np.zeros((P_DEV, C, F), np.float32)
den = np.zeros((P_DEV, C), np.float32)
# exactly ceil(8 * 0.5) = 4 nonzero channels per shard -> lossless
for s in range(P_DEV):
    keep = rng.choice(C, size=4, replace=False)
    den[s, keep] = 1.0
    num[s, keep] = rng.normal(size=(4, F))

def body(n, d):
    return f(n[0], d[0])

num_tot, den_tot, overflow = shard_reduce(body, jnp.asarray(num),
                                          jnp.asarray(den))
assert float(overflow) == 0.0, float(overflow)
on, od = dense_oracle(num, den)
np.testing.assert_allclose(np.asarray(num_tot), on, rtol=1e-5, atol=1e-6)
print("OK")
"""
    assert "OK" in _run_sub(code)
