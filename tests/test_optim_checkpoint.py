"""Optimizers (pure JAX) + checkpoint round-trips."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import load_checkpoint, save_checkpoint
from repro.optim import adafactor, adam, adamw, sgd
from repro.optim.optimizers import apply_updates


def _rosenbrock_ish(params):
    x, y = params["x"], params["y"]
    return jnp.sum((1 - x) ** 2) + 5.0 * jnp.sum((y - x ** 2) ** 2)


@pytest.mark.parametrize("opt_fn", [
    lambda: sgd(0.02), lambda: sgd(0.004, momentum=0.9),
    lambda: adam(0.05), lambda: adamw(0.05, weight_decay=0.0),
    lambda: adafactor(0.05),
])
def test_optimizer_minimises_quadratic(opt_fn):
    opt = opt_fn()
    params = {"x": jnp.asarray([[-1.0, 2.0]]), "y": jnp.asarray([[2.0, -1.0]])}
    state = opt.init(params)

    @jax.jit
    def step(params, state):
        loss, g = jax.value_and_grad(_rosenbrock_ish)(params)
        ups, state = opt.update(g, state, params)
        return apply_updates(params, ups), state, loss

    l0 = float(_rosenbrock_ish(params))
    for _ in range(300):
        params, state, loss = step(params, state)
    assert float(loss) < 0.05 * l0


def test_adamw_decays_weights():
    opt = adamw(0.1, weight_decay=0.5)
    params = {"w": jnp.full((4,), 10.0)}
    state = opt.init(params)
    g = {"w": jnp.zeros(4)}
    ups, state = opt.update(g, state, params)
    p2 = apply_updates(params, ups)
    assert float(p2["w"][0]) < 10.0


def test_checkpoint_roundtrip(tmp_path):
    key = jax.random.PRNGKey(0)
    tree = {"a": {"w": jax.random.normal(key, (4, 6)),
                  "b": jnp.zeros(6, jnp.bfloat16)},
            "step": jnp.asarray(7, jnp.int32)}
    path = tmp_path / "ckpt.npz"
    save_checkpoint(path, tree, metadata={"round": 3})
    like = jax.tree_util.tree_map(
        lambda l: jax.ShapeDtypeStruct(l.shape, l.dtype), tree)
    restored, meta = load_checkpoint(path, like)
    assert meta["round"] == 3
    for a, b in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
        assert a.dtype == b.dtype


def test_checkpoint_survives_kill_mid_write(tmp_path, monkeypatch):
    """A process dying mid-save must leave the PREVIOUS checkpoint fully
    intact: the atomic temp+fsync+rename path never tears the live file."""
    from repro.checkpoint import io as ckpt_io

    tree_v1 = {"w": jnp.arange(12.0).reshape(3, 4)}
    path = tmp_path / "ckpt.npz"
    save_checkpoint(path, tree_v1, metadata={"round": 1})

    def _die(fd):
        raise OSError("simulated power loss mid-write")

    monkeypatch.setattr(ckpt_io.os, "fsync", _die)
    with pytest.raises(OSError, match="power loss"):
        save_checkpoint(path, {"w": jnp.full((3, 4), 9.0)},
                        metadata={"round": 2})
    monkeypatch.undo()

    like = {"w": jax.ShapeDtypeStruct((3, 4), jnp.float32)}
    restored, meta = load_checkpoint(path, like)
    assert meta["round"] == 1
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(tree_v1["w"]))


def test_checkpoint_shape_mismatch_raises(tmp_path):
    tree = {"w": jnp.zeros((2, 2))}
    path = tmp_path / "c.npz"
    save_checkpoint(path, tree)
    bad = {"w": jax.ShapeDtypeStruct((3, 3), jnp.float32)}
    with pytest.raises(ValueError):
        load_checkpoint(path, bad)
