"""Byzantine-robust Eq. (4) variants (core/aggregation.py ``robust=`` +
``ProtocolConfig.robust_agg``): spec parsing, hand-computed reductions,
the mean-spec bit-identity contract on every engine path, and the
adversarial-client survivability scenario.

Pins the robust-aggregation contracts:

* spec parsing — ``"mean" | "trimmed[:beta]" | "clip[:factor]"`` round-
  trip as plain strings; malformed specs fail at config time;
* trimmed mean — coordinate-wise rank trimming drops exactly the
  ``floor(beta * n_valid)`` extremes among VALID contributors (mask 1,
  weight > 0) before the weighted Eq. (4) sums (hand-computed);
* norm clipping — each client's whole-tree masked update is scaled to
  ``factor x median`` participant norm before the standard mean
  (hand-computed); ``clip`` without ``prev_global`` is a config error;
* ``robust_agg="mean"`` is BIT-IDENTICAL to the default on the batched,
  scanned, grouped, and (1-device) sharded engines — the inert-config
  contract;
* survivability — a corrupt-but-finite adversarial client drags the
  mean-aggregated global arbitrarily far while the trimmed mean holds;
* routing — the reference loop rejects robust specs (engine-fused
  feature) and the grouped engine rejects robust + mesh.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.comm import CommConfig
from repro.core import FedDDServer, ProtocolConfig, aggregation, run_scheme
from repro.core.allocation import ClientTelemetry
from repro.core.round_engine import GroupedRoundEngine
from repro.core.selection import SelectionConfig
from repro.launch import mesh as mesh_mod

pytestmark = pytest.mark.flcore


# --- shared fixtures ---------------------------------------------------------

def _params(key, w=12):
    k1, k2 = jax.random.split(key)
    return {"fc0": {"w": jax.random.normal(k1, (20, w)), "b": jnp.zeros(w)},
            "fc1": {"w": jax.random.normal(k2, (w, 5)), "b": jnp.zeros(5)}}


def _nbytes(p):
    return float(sum(l.size * l.dtype.itemsize
                     for l in jax.tree_util.tree_leaves(p)))


def _tel(n, nbytes, seed=0):
    rng = np.random.default_rng(seed)
    return ClientTelemetry(
        model_bytes=np.full(n, nbytes) if np.isscalar(nbytes)
        else np.asarray(nbytes),
        uplink_rate=rng.uniform(1e3, 5e3, n),
        downlink_rate=rng.uniform(5e3, 2e4, n),
        compute_latency=rng.uniform(1.0, 5.0, n),
        num_samples=rng.integers(10, 50, n).astype(float),
        label_coverage=rng.uniform(0.5, 1.0, n),
        train_loss=np.ones(n))


def _ltf(p, idx, key):
    return (jax.tree_util.tree_map(
        lambda x: x * 0.99 + 0.01 * jax.random.normal(key, x.shape), p),
        1.0 / (idx + 1.0))


def _trees_equal(a, b):
    return all(bool(jnp.all(x == y)) for x, y in zip(
        jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)))


def _histories_equal(ha, hb):
    assert len(ha) == len(hb)
    for ra, rb in zip(ha, hb):
        assert ra.mean_loss == rb.mean_loss
        assert ra.sim_time == rb.sim_time
        assert ra.uploaded_bytes == rb.uploaded_bytes
        assert ra.wire_bytes == rb.wire_bytes
        np.testing.assert_array_equal(ra.dropout_rates, rb.dropout_rates)


# --- spec parsing -------------------------------------------------------------

def test_parse_robust_agg_specs():
    assert aggregation.parse_robust_agg(None) == ("mean", 0.0)
    assert aggregation.parse_robust_agg("mean") == ("mean", 0.0)
    assert aggregation.parse_robust_agg("trimmed") == ("trimmed", 0.1)
    assert aggregation.parse_robust_agg("trimmed:0.25") == ("trimmed", 0.25)
    assert aggregation.parse_robust_agg("clip") == ("clip", 1.0)
    assert aggregation.parse_robust_agg("clip:3.5") == ("clip", 3.5)
    with pytest.raises(ValueError, match="takes no parameter"):
        aggregation.parse_robust_agg("mean:0.1")
    with pytest.raises(ValueError, match=r"beta must be in \[0,0.5\)"):
        aggregation.parse_robust_agg("trimmed:0.5")
    with pytest.raises(ValueError, match="clip factor"):
        aggregation.parse_robust_agg("clip:0")
    with pytest.raises(ValueError, match="unknown robust_agg"):
        aggregation.parse_robust_agg("krum")
    # ... and ProtocolConfig validates at construction time
    with pytest.raises(ValueError, match="unknown robust_agg"):
        ProtocolConfig(robust_agg="median-of-means")


# --- hand-computed reductions -------------------------------------------------

def test_trimmed_mean_hand_computed():
    """5 clients, unit weights, full masks, beta=0.2: k = floor(1) = 1,
    so the min (0) and the outlier (100) drop and every coordinate
    averages [1, 2, 3] -> 2."""
    vals = jnp.asarray([0.0, 1.0, 2.0, 3.0, 100.0])
    stacked = {"w": jnp.broadcast_to(vals[:, None], (5, 3))}
    masks = {"w": jnp.ones((5, 1))}
    out = aggregation.aggregate_sparse_stacked(
        stacked, masks, np.ones(5), robust="trimmed:0.2")
    np.testing.assert_allclose(np.asarray(out["w"]), 2.0, atol=0)


def test_trimmed_mean_counts_only_valid_contributors():
    """Masked-out and zero-weight rows are invalid: they neither rank nor
    aggregate, and k tracks the per-coordinate VALID count."""
    vals = jnp.asarray([0.0, 1.0, 2.0, 3.0, 100.0])
    stacked = {"w": jnp.broadcast_to(vals[:, None], (5, 2))}
    # client 1 masked out of coordinate 0 only
    masks = {"w": jnp.asarray([[1.0, 1.0], [0.0, 1.0], [1.0, 1.0],
                               [1.0, 1.0], [1.0, 1.0]])}
    out = aggregation.aggregate_sparse_stacked(
        stacked, masks, np.ones(5), robust="trimmed:0.25")
    got = np.asarray(out["w"])
    # coord 0: valid {0,2,3,100}, k=1 -> mean(2,3); coord 1: valid
    # {0,1,2,3,100}, k=1 -> mean(1,2,3)
    np.testing.assert_allclose(got[0], 2.5, atol=0)
    np.testing.assert_allclose(got[1], 2.0, atol=0)
    # zero-weight outlier: excluded from the ranks entirely, so the trim
    # falls on the remaining extremes — valid {0,1,2,3}, k=1 -> mean(1,2)
    out2 = aggregation.aggregate_sparse_stacked(
        {"w": vals[:, None]}, {"w": jnp.ones((5, 1))},
        np.asarray([1.0, 1.0, 1.0, 1.0, 0.0]), robust="trimmed:0.25")
    np.testing.assert_allclose(np.asarray(out2["w"])[0], 1.5, atol=0)


def test_trimmed_mean_empty_coordinate_falls_back_to_prev_global():
    stacked = {"w": jnp.asarray([[1.0], [2.0]])}
    masks = {"w": jnp.zeros((2, 1))}
    out = aggregation.aggregate_sparse_stacked(
        stacked, masks, np.ones(2),
        prev_global={"w": jnp.asarray([7.0])}, robust="trimmed:0.2")
    np.testing.assert_array_equal(np.asarray(out["w"]), [7.0])


def test_clip_hand_computed_and_requires_prev_global():
    """Norms [1000, 1, 2, 3] vs factor x median = 2.5: BOTH
    above-threshold updates (1000 and 3) scale onto the 2.5 ball and the
    Eq. (4) mean becomes (2.5 + 1 + 2 + 2.5) / 4 = 2."""
    stacked = {"w": jnp.asarray([[1000.0], [1.0], [2.0], [3.0]])}
    masks = {"w": jnp.ones((4, 1))}
    out = aggregation.aggregate_sparse_stacked(
        stacked, masks, np.ones(4),
        prev_global={"w": jnp.zeros(1)}, robust="clip:1.0")
    np.testing.assert_allclose(np.asarray(out["w"]), [2.0], rtol=1e-6)
    with pytest.raises(ValueError, match="needs prev_global"):
        aggregation.aggregate_sparse_stacked(
            stacked, masks, np.ones(4), robust="clip:1.0")


# --- the mean-spec bit-identity contract on every engine path -----------------

def _run_batched(n=6, robust=None, ltf=_ltf, rounds=3):
    params = _params(jax.random.PRNGKey(0))
    kw = dict(rounds=rounds, a_server=0.6, h=3, seed=0)
    if robust is not None:
        kw["robust_agg"] = robust
    return run_scheme("feddd", params, _tel(n, _nbytes(params)), ltf,
                      None, batched=True, **kw)


def test_mean_spec_bit_identical_batched():
    ref = _run_batched()
    got = _run_batched(robust="mean")
    assert _trees_equal(ref.global_params, got.global_params)
    _histories_equal(ref.history, got.history)


def test_mean_spec_bit_identical_grouped():
    n, widths = 6, (12, 8, 6)
    gp = _params(jax.random.PRNGKey(0), max(widths))
    clients = [_params(jax.random.PRNGKey(100 + i), widths[i % 3])
               for i in range(n)]
    tel = _tel(n, [_nbytes(p) for p in clients])
    kw = dict(rounds=3, a_server=0.6, h=3, seed=0)
    ref = run_scheme("feddd", gp, tel, _ltf, None,
                     client_params=clients, **kw)
    got = run_scheme("feddd", gp, tel, _ltf, None,
                     client_params=clients, robust_agg="mean", **kw)
    assert _trees_equal(ref.global_params, got.global_params)
    _histories_equal(ref.history, got.history)


def _scan_fixture(n=8, seed=0):
    params = _params(jax.random.PRNGKey(seed))
    tel = _tel(n, _nbytes(params), seed=seed)

    @jax.jit
    def batched(stacked, key):
        new = jax.tree_util.tree_map(
            lambda x: x * 0.99 + 0.01 * jax.random.normal(
                jax.random.fold_in(key, 1), x.shape), stacked)
        l0 = jax.tree_util.tree_leaves(new)[0]
        losses = jnp.mean(jnp.abs(l0.reshape(l0.shape[0], -1)), axis=1)
        return new, losses
    return params, tel, batched


def test_mean_spec_bit_identical_scanned():
    params, tel, batched = _scan_fixture()
    kw = dict(scheme="feddd", allocator="jax", rounds_per_dispatch=2,
              rounds=4, a_server=0.6, h=3, seed=0)
    ref = FedDDServer(params, ProtocolConfig(**kw),
                      tel).run(batched_train_fn=batched)
    got = FedDDServer(params, ProtocolConfig(robust_agg="mean", **kw),
                      tel).run(batched_train_fn=batched)
    assert _trees_equal(ref.global_params, got.global_params)
    _histories_equal(ref.history, got.history)


def test_mean_spec_bit_identical_sharded_single_device():
    params = _params(jax.random.PRNGKey(0))
    n = 6
    kw = dict(rounds=3, a_server=0.6, h=3, seed=0, mesh=1)
    ref = run_scheme("feddd", params, _tel(n, _nbytes(params)), _ltf,
                     None, **kw)
    got = run_scheme("feddd", params, _tel(n, _nbytes(params)), _ltf,
                     None, robust_agg="mean", **kw)
    assert _trees_equal(ref.global_params, got.global_params)
    _histories_equal(ref.history, got.history)


def test_sharded_robust_matches_batched_on_one_device():
    """The dense-gather fallback on a 1-device mesh is the identity, so
    sharded trimmed == batched trimmed bit for bit."""
    params = _params(jax.random.PRNGKey(0))
    n = 6
    kw = dict(rounds=3, a_server=0.6, h=3, seed=0,
              robust_agg="trimmed:0.25")
    eng = run_scheme("feddd", params, _tel(n, _nbytes(params)), _ltf,
                     None, batched=True, **kw)
    shd = run_scheme("feddd", params, _tel(n, _nbytes(params)), _ltf,
                     None, mesh=1, **kw)
    assert _trees_equal(eng.global_params, shd.global_params)
    _histories_equal(eng.history, shd.history)


# --- survivability: adversarial client ----------------------------------------

def _adversarial_ltf(p, idx, key):
    """Client 0 is corrupt-but-finite: it returns an update every screen
    passes (all values finite) that drags a weighted mean far away."""
    if idx == 0:
        return jax.tree_util.tree_map(lambda x: x + 500.0, p), 1.0
    return _ltf(p, idx, key)


def test_adversarial_client_mean_diverges_trimmed_and_clip_hold():
    mean = _run_batched(n=8, ltf=_adversarial_ltf)
    trimmed = _run_batched(n=8, robust="trimmed:0.25", ltf=_adversarial_ltf)
    clip = _run_batched(n=8, robust="clip:2.0", ltf=_adversarial_ltf)
    peak = lambda r: float(np.max(np.abs(np.asarray(  # noqa: E731
        r.global_params["fc0"]["w"]))))
    assert peak(mean) > 50.0            # the mean is dragged away
    assert peak(trimmed) < 10.0         # the trimmed mean holds
    assert peak(clip) < peak(mean) / 2  # clipping bounds the influence
    for leaf in jax.tree_util.tree_leaves(trimmed.global_params):
        assert bool(jnp.all(jnp.isfinite(leaf)))


def test_robust_specs_close_to_mean_on_clean_fleet():
    """With no adversary the robust variants track the mean closely —
    robustness costs little on clean data."""
    mean = _run_batched(n=8)
    trimmed = _run_batched(n=8, robust="trimmed:0.125")
    for a, b in zip(jax.tree_util.tree_leaves(mean.global_params),
                    jax.tree_util.tree_leaves(trimmed.global_params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=0.2)


# --- routing guards -----------------------------------------------------------

def test_loop_path_rejects_robust_specs():
    params = _params(jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="fused into the engine"):
        run_scheme("feddd", params, _tel(4, _nbytes(params)), _ltf, None,
                   batched=False, robust_agg="trimmed",
                   rounds=1, a_server=0.6, seed=0)


def test_grouped_engine_rejects_robust_on_mesh():
    mesh = mesh_mod.resolve_client_mesh(1)
    with pytest.raises(NotImplementedError, match="single-device"):
        GroupedRoundEngine(SelectionConfig(), CommConfig(), mesh,
                           "trimmed:0.2")
    # mean on a mesh and robust off-mesh both construct fine
    GroupedRoundEngine(SelectionConfig(), CommConfig(), mesh, "mean")
    GroupedRoundEngine(SelectionConfig(), CommConfig(), None, "clip:2.0")
