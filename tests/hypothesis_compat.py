"""Optional-hypothesis shim: ``from hypothesis_compat import given, ...``.

requirements.txt pins hypothesis and CI installs it, but the tier-1 suite
must still COLLECT (and the non-property tests must still RUN) in an
environment without it.  When hypothesis is importable this module re-exports
the real API; otherwise ``@given`` replaces the test with a skip stub and
``st``/``settings`` become inert placeholders.
"""

import pytest

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised only without hypothesis
    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        def deco(fn):
            # zero-arg stub: the real test's parameters are hypothesis
            # strategies, which pytest must not mistake for fixtures.
            @pytest.mark.skip(reason="hypothesis not installed")
            def _skipped():
                pass  # pragma: no cover
            _skipped.__name__ = fn.__name__
            _skipped.__doc__ = fn.__doc__
            return _skipped
        return deco

    def settings(*_args, **_kwargs):
        def deco(fn):
            return fn
        return deco

    class _InertStrategies:
        def __getattr__(self, _name):
            def _strategy(*_args, **_kwargs):
                return None
            return _strategy

    st = _InertStrategies()
