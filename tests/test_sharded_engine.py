"""Client-sharded SPMD engines: mesh helpers, bit-identity, parity.

Contracts pinned here (see core/round_engine.py ShardedRoundEngine):

* ``launch.mesh`` helpers clamp to divisors and resolve mesh specs;
* on a 1-DEVICE mesh the sharded step is BIT-IDENTICAL to the
  single-device ``BatchedRoundEngine`` (psum over one device is the
  identity, masks/QDQ fold GLOBAL fleet ids, and the Eq. (4) partials are
  the same arithmetic by construction);
* on multi-device meshes parity is allclose (per-shard partial sums then
  psum reorder the float32 reduction — the standard SPMD ulp caveat);
* the sparse collective's ``overflow`` certifies lossless compaction;
* the protocol and sim-runner routing/validation around ``mesh=``.

Multi-device cases run in a subprocess with
``--xla_force_host_platform_device_count`` so the main pytest process
keeps a single device (conftest policy)."""

import subprocess
import sys
import textwrap
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm.payload import (WireSpec, account_collective,
                                collective_payload_bytes)
from repro.core import round_engine
from repro.core.protocol import ProtocolConfig
from repro.core.selection import SelectionConfig
from repro.launch import mesh as mesh_mod

pytestmark = pytest.mark.flcore

SRC = str(Path(__file__).resolve().parents[1] / "src")


def _run_sub(code: str, devices: int = 8) -> str:
    env = {"XLA_FLAGS": f"--xla_force_host_platform_device_count={devices}",
           "PYTHONPATH": SRC, "PATH": "/usr/bin:/bin"}
    import os
    env.update({k: v for k, v in os.environ.items()
                if k not in env and k != "XLA_FLAGS"})
    res = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=600)
    assert res.returncode == 0, res.stderr[-3000:]
    return res.stdout


# ------------------------------------------------------- mesh helpers

def test_make_host_mesh_clamps_non_divisible_axes():
    """Axis sizes that do not divide the device count clamp to the
    largest divisor instead of erroring."""
    m = mesh_mod.make_host_mesh(data=3, model=1)   # 1 device visible
    assert m.devices.size == 1
    assert m.axis_names == ("data", "model")


def test_make_client_mesh_single_device():
    m = mesh_mod.make_client_mesh()
    assert m.axis_names == ("clients",)
    assert m.devices.size == jax.device_count()


def test_resolve_client_mesh_accepts_true_int_and_mesh():
    m_all = mesh_mod.resolve_client_mesh(True)
    assert m_all.devices.size == jax.device_count()
    m_one = mesh_mod.resolve_client_mesh(1)
    assert m_one.devices.size == 1
    assert mesh_mod.resolve_client_mesh(m_one) is m_one


def test_resolve_client_mesh_rejects_wrong_axis():
    import numpy as _np
    bad = jax.sharding.Mesh(_np.asarray(jax.devices()), ("pod",))
    with pytest.raises(ValueError):
        mesh_mod.resolve_client_mesh(bad)
    with pytest.raises(TypeError):
        mesh_mod.resolve_client_mesh("clients")


def test_host_mesh_non_divisible_counts_subprocess():
    """6 devices, data=4 requested -> clamps to 3 (largest divisor)."""
    code = """
    import jax
    from repro.launch.mesh import make_host_mesh, make_client_mesh
    m = make_host_mesh(data=4, model=1)
    assert m.shape["data"] == 3, dict(m.shape)
    c = make_client_mesh(4)
    assert c.devices.size == 4 and c.axis_names == ("clients",)
    print("OK")
    """
    assert "OK" in _run_sub(code, devices=6)


# ------------------------------------------- engine-level bit identity

def _fleet(n=10, seed=0):
    k = jax.random.PRNGKey(seed)
    gparams = {"w": jax.random.normal(jax.random.fold_in(k, 0), (4, 8)),
               "b": jax.random.normal(jax.random.fold_in(k, 1), (8,))}
    stacked = jax.tree_util.tree_map(
        lambda l: jnp.stack([l * (1 + 0.01 * i) for i in range(n)]), gparams)
    new = jax.tree_util.tree_map(lambda l: l * 1.01 + 0.002, stacked)
    d = jnp.asarray(np.linspace(0.0, 0.6, n), jnp.float32)
    w = jnp.asarray(np.arange(1, n + 1), jnp.float32)
    return gparams, stacked, new, d, w


def test_one_device_mesh_is_bit_identical_to_batched_engine():
    gparams, stacked, new, d, w = _fleet()
    cfg = SelectionConfig()
    base = round_engine.BatchedRoundEngine(cfg)
    shard = round_engine.ShardedRoundEngine(
        cfg, base.comm, mesh=mesh_mod.make_client_mesh(1))
    rk = jax.random.PRNGKey(3)
    for fr, dm in [(False, False), (True, False), (False, True)]:
        o1 = base.step(stacked, new, gparams, d, w, rk,
                       full_round=fr, dense_masks=dm)
        o2 = shard.step(stacked, new, gparams, d, w, rk,
                        full_round=fr, dense_masks=dm)
        for a, b in zip(jax.tree_util.tree_leaves(o1.global_params),
                        jax.tree_util.tree_leaves(o2.global_params)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree_util.tree_leaves(o1.client_params),
                        jax.tree_util.tree_leaves(o2.client_params)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        np.testing.assert_array_equal(np.asarray(o1.densities),
                                      np.asarray(o2.densities))


def test_sharded_engine_rejects_overrides_and_bad_config():
    gparams, stacked, new, d, w = _fleet()
    eng = round_engine.ShardedRoundEngine(
        SelectionConfig(), mesh=mesh_mod.make_client_mesh(1))
    with pytest.raises(NotImplementedError):
        eng.step(stacked, new, gparams, d, w, jax.random.PRNGKey(0),
                 full_round=False, stacked_upload=new)
    with pytest.raises(ValueError):
        round_engine.ShardedRoundEngine(SelectionConfig())   # no mesh
    with pytest.raises(ValueError):
        round_engine.ShardedRoundEngine(
            SelectionConfig(), mesh=mesh_mod.make_client_mesh(1),
            collective="ring")
    with pytest.raises(ValueError):
        round_engine.ShardedRoundEngine(
            SelectionConfig(), mesh=mesh_mod.make_client_mesh(1),
            keep_fraction=0.0)


# ------------------------------------------------ multi-device parity

def test_eight_device_parity_dense_and_sparse():
    """13 clients (non-divisible) over 8 devices: allclose to the
    single-device engine for the dense psum and the kf=1.0 sparse route;
    sparse kf<1 with bounded dropout stays lossless (overflow 0)."""
    code = """
    import numpy as np, jax, jax.numpy as jnp
    from repro.core import round_engine
    from repro.core.selection import SelectionConfig
    from repro.launch import mesh as mesh_mod

    n = 13
    k = jax.random.PRNGKey(0)
    gparams = {"w": jax.random.normal(jax.random.fold_in(k, 0), (4, 8)),
               "b": jax.random.normal(jax.random.fold_in(k, 1), (8,))}
    stacked = jax.tree_util.tree_map(
        lambda l: jnp.stack([l * (1 + 0.01 * i) for i in range(n)]),
        gparams)
    new = jax.tree_util.tree_map(lambda l: l * 1.01 + 0.002, stacked)
    w = jnp.asarray(np.arange(1, n + 1), jnp.float32)
    rk = jax.random.PRNGKey(3)
    cfg = SelectionConfig()
    base = round_engine.BatchedRoundEngine(cfg)
    m = mesh_mod.make_client_mesh()
    assert m.devices.size == 8

    def check(eng, d, expect_overflow_zero=True):
        o1 = base.step(stacked, new, gparams, d, w, rk, full_round=False)
        o2 = eng.step(stacked, new, gparams, d, w, rk, full_round=False)
        for a, b in zip(jax.tree_util.tree_leaves(o1.global_params),
                        jax.tree_util.tree_leaves(o2.global_params)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=2e-6, atol=2e-6)
        np.testing.assert_array_equal(np.asarray(o1.densities),
                                      np.asarray(o2.densities))
        if o2.collective_overflow is not None and expect_overflow_zero:
            assert float(o2.collective_overflow) == 0.0

    d_mixed = jnp.asarray(np.linspace(0.0, 0.6, n), jnp.float32)
    check(round_engine.ShardedRoundEngine(cfg, base.comm, mesh=m), d_mixed)
    check(round_engine.ShardedRoundEngine(cfg, base.comm, mesh=m,
                                          collective="sparse",
                                          keep_fraction=1.0), d_mixed)
    # high uniform dropout: every client keeps ceil(8*0.25)=2 channels,
    # any shard's union of <= 2 clients is <= 4 <= K=ceil(8*0.8)=7
    d_hi = jnp.full((n,), 0.75, jnp.float32)
    check(round_engine.ShardedRoundEngine(cfg, base.comm, mesh=m,
                                          collective="sparse",
                                          keep_fraction=0.8), d_hi)
    # low dropout overflows the K=7 buffer: certificate > 0
    eng = round_engine.ShardedRoundEngine(cfg, base.comm, mesh=m,
                                          collective="sparse",
                                          keep_fraction=0.8)
    o = eng.step(stacked, new, gparams, jnp.zeros((n,), jnp.float32), w,
                 rk, full_round=False)
    assert float(o.collective_overflow) > 0.0
    print("OK")
    """
    assert "OK" in _run_sub(code)


def test_grouped_sharded_parity_eight_devices():
    """Ragged fleet: grouped engine with a mesh matches the unsharded
    grouped step (allclose; densities exact)."""
    code = """
    import numpy as np, jax, jax.numpy as jnp
    from repro.core import round_engine as re_mod, coverage as cov_mod
    from repro.core.selection import SelectionConfig
    from repro.fl.heterogeneity import group_by_shape
    from repro.launch import mesh as mesh_mod

    rng = jax.random.PRNGKey(0)
    n = 10
    gparams = {"w1": jax.random.normal(jax.random.fold_in(rng, 0), (4, 8)),
               "b1": jax.random.normal(jax.random.fold_in(rng, 1), (8,))}
    def sub(p, frac):
        return jax.tree_util.tree_map(
            lambda l: l[tuple(slice(0, max(1, int(s * frac)))
                              for s in l.shape)], p)
    cp = [sub(gparams, 1.0) if i % 2 == 0 else sub(gparams, 0.5)
          for i in range(n)]
    cp = [jax.tree_util.tree_map(lambda l, i=i: l * (1 + 0.01 * i), p)
          for i, p in enumerate(cp)]
    full_w = cov_mod.channel_widths(gparams, -1)
    cw = [cov_mod.channel_widths(p, -1) for p in cp]
    cr = cov_mod.coverage_rates(cw, full_w)
    groups = group_by_shape(cp)
    coverage = [cov_mod.coverage_pytree(cp[g.indices[0]], cr, -1)
                for g in groups]
    batches = []
    for g, cov in zip(groups, coverage):
        stacked = re_mod.stack_pytrees([cp[i] for i in g.indices])
        new = jax.tree_util.tree_map(lambda l: l * 1.01 + 0.002, stacked)
        batches.append(re_mod.GroupBatch(
            indices=jnp.asarray(g.indices, jnp.int32),
            stacked_old=stacked, stacked_new=new, coverage=cov,
            dropout=jnp.asarray([0.3] * g.size, jnp.float32)))
    w = jnp.asarray(np.arange(1, n + 1), jnp.float32)
    rk = jax.random.PRNGKey(3)
    cfg = SelectionConfig()
    base = re_mod.GroupedRoundEngine(cfg)
    shard = re_mod.GroupedRoundEngine(cfg, base.comm,
                                      mesh_mod.make_client_mesh())
    for fr, dm in [(False, False), (True, False), (False, True)]:
        o1 = base.step(batches, gparams, w, rk, full_round=fr,
                       dense_masks=dm)
        o2 = shard.step(batches, gparams, w, rk, full_round=fr,
                        dense_masks=dm)
        for a, b in zip(jax.tree_util.tree_leaves(o1.global_params),
                        jax.tree_util.tree_leaves(o2.global_params)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=2e-6, atol=2e-6)
        np.testing.assert_array_equal(np.asarray(o1.densities),
                                      np.asarray(o2.densities))
    print("OK")
    """
    assert "OK" in _run_sub(code)


# --------------------------------------------------- protocol routing

def _telemetry(n=13, seed=0):
    from repro.core.allocation import ClientTelemetry
    rng = np.random.default_rng(seed)
    return ClientTelemetry(
        model_bytes=np.full(n, 4096.0),
        uplink_rate=rng.uniform(1e3, 5e3, n),
        downlink_rate=rng.uniform(5e3, 2e4, n),
        compute_latency=rng.uniform(1.0, 5.0, n),
        num_samples=rng.integers(10, 50, n).astype(float),
        label_coverage=rng.uniform(0.5, 1.0, n),
        train_loss=np.ones(n))


def _gparams():
    k = jax.random.PRNGKey(42)
    return {"w": jax.random.normal(k, (4, 8)), "b": jnp.zeros((8,))}


def _btrain(stacked, rng):
    new = jax.tree_util.tree_map(lambda l: l * 1.01 + 0.003, stacked)
    n = jax.tree_util.tree_leaves(stacked)[0].shape[0]
    return new, jnp.ones((n,))


def test_protocol_mesh_one_bit_identical_to_engine_executor():
    from repro.core.protocol import FedDDServer
    tel = _telemetry()

    def run(**kw):
        cfg = ProtocolConfig(selection=SelectionConfig(), rounds=4,
                             seed=0, **kw)
        srv = FedDDServer(_gparams(), cfg, tel)
        srv.run(batched_train_fn=_btrain)
        return srv

    s0, s1 = run(), run(mesh=1)
    for a, b in zip(jax.tree_util.tree_leaves(s0.global_params),
                    jax.tree_util.tree_leaves(s1.global_params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_protocol_config_mesh_validations():
    sel = SelectionConfig()
    with pytest.raises(ValueError):
        ProtocolConfig(selection=sel, mesh=1, rounds_per_dispatch=2)
    with pytest.raises(ValueError):
        ProtocolConfig(selection=sel, mesh=1, mesh_collective="ring")
    with pytest.raises(ValueError):
        ProtocolConfig(selection=sel, mesh=1, mesh_keep_fraction=0.0)


def test_protocol_mesh_requires_engine_backed_execution():
    from repro.core.protocol import FedDDServer
    cfg = ProtocolConfig(selection=SelectionConfig(), mesh=1,
                         batched=False, rounds=2)
    srv = FedDDServer(_gparams(), cfg, _telemetry())
    with pytest.raises(ValueError):
        srv.run(local_train_fn=lambda p, i, r: (p, 1.0))


def test_protocol_eight_device_parity_subprocess():
    code = """
    import numpy as np, jax, jax.numpy as jnp
    from repro.core.protocol import ProtocolConfig, FedDDServer
    from repro.core.selection import SelectionConfig
    from repro.core.allocation import ClientTelemetry

    n = 13
    rng = np.random.default_rng(0)
    tel = ClientTelemetry(
        model_bytes=np.full(n, 4096.0),
        uplink_rate=rng.uniform(1e3, 5e3, n),
        downlink_rate=rng.uniform(5e3, 2e4, n),
        compute_latency=rng.uniform(1.0, 5.0, n),
        num_samples=rng.integers(10, 50, n).astype(float),
        label_coverage=rng.uniform(0.5, 1.0, n),
        train_loss=np.ones(n))

    def params():
        k = jax.random.PRNGKey(42)
        return {"w": jax.random.normal(k, (4, 8)), "b": jnp.zeros((8,))}

    def btrain(stacked, rng_):
        new = jax.tree_util.tree_map(lambda l: l * 1.01 + 0.003, stacked)
        return new, jnp.ones((stacked["w"].shape[0],))

    def run(**kw):
        cfg = ProtocolConfig(selection=SelectionConfig(), rounds=4,
                             seed=0, **kw)
        srv = FedDDServer(params(), cfg, tel)
        srv.run(batched_train_fn=btrain)
        return srv

    s0 = run()
    for kw in (dict(mesh=True),
               dict(mesh=True, mesh_collective="sparse",
                    mesh_keep_fraction=1.0)):
        s = run(**kw)
        for a, b in zip(jax.tree_util.tree_leaves(s0.global_params),
                        jax.tree_util.tree_leaves(s.global_params)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=2e-6, atol=2e-6)
    print("OK")
    """
    assert "OK" in _run_sub(code)


# -------------------------------------------------- sim-runner routing

def test_sim_mesh_one_bit_identical():
    from repro.core.allocation import ClientTelemetry  # noqa: F401
    from repro.sim.runner import SimConfig, run_sim
    tel = _telemetry()

    def train(p, i, r):
        return jax.tree_util.tree_map(lambda l: l * 1.01 + 0.003, p), 1.0

    r0 = run_sim("feddd", _gparams(), tel, train, rounds=3, seed=0)
    r1 = run_sim("feddd", _gparams(), tel, train, rounds=3, seed=0,
                 mesh=1)
    for a, b in zip(jax.tree_util.tree_leaves(r0.global_params),
                    jax.tree_util.tree_leaves(r1.global_params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert [h.sim_time for h in r0.history] == \
        [h.sim_time for h in r1.history]


def test_sim_mesh_guards():
    from repro.sim.faults import RandomFaults
    from repro.sim.runner import SimConfig, run_sim
    tel = _telemetry()

    def train(p, i, r):
        return p, 1.0

    with pytest.raises(ValueError):
        run_sim("feddd", _gparams(), tel, train, rounds=2, mesh=1,
                faults=RandomFaults(corrupt_rate=0.5))
    with pytest.raises(ValueError):
        run_sim("feddd", _gparams(), tel, train, rounds=2, mesh=1,
                sim=SimConfig(policy="deadline",
                              policy_kw={"partial": True}))
    # ragged fleet + sparse collective: grouped reduces dense-only
    cp = [_gparams() if i % 2 == 0 else
          jax.tree_util.tree_map(lambda l: l[..., :4], _gparams())
          for i in range(13)]
    with pytest.raises(ValueError):
        run_sim("feddd", _gparams(), tel, train, rounds=2, mesh=1,
                client_params=cp, mesh_collective="sparse",
                mesh_keep_fraction=0.5)


# --------------------------------------------- collective byte model

def test_collective_payload_bytes_dense_vs_sparse():
    spec = WireSpec(((8, 32), (8, 8)))
    dense = collective_payload_bytes(spec, mode="dense")
    # full f32 numerator + (C,) den profile per leaf
    assert dense == (32 + 8) * 4.0 + (8 + 8) * 4.0
    sparse = collective_payload_bytes(spec, mode="sparse", k_fraction=0.5)
    # K=4 rows of elements/C values + K idx + K den rows, per leaf
    assert sparse == (4 * 4 * 4.0 + 4 * 8.0) + (4 * 1 * 4.0 + 4 * 8.0)
    assert sparse < dense
    with pytest.raises(ValueError):
        collective_payload_bytes(spec, mode="ring")


def test_account_collective_hooks_recorder():
    class _Rec:
        active = True

        def __init__(self):
            self.calls = []

        def collective(self, dense, wire):
            self.calls.append((dense, wire))

    spec = WireSpec(((8, 32),))
    rec = _Rec()
    dense, actual = account_collective(spec, 4, mode="sparse",
                                       k_fraction=0.5, obs=rec)
    assert rec.calls == [(dense, actual)]
    assert dense == 4 * collective_payload_bytes(spec, mode="dense")
    assert actual < dense
    d2, a2 = account_collective(spec, 4, mode="dense")
    assert d2 == a2
