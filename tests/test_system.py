"""End-to-end behaviour tests for the FedDD system (paper Algorithm 1).

These run a real (small) federated training on synthetic data and assert
the paper's qualitative claims:

  * FedDD reaches a target accuracy in less simulated time than FedAvg
    (the headline T2A claim, >75% reduction in the paper);
  * FedDD keeps ALL clients participating while client-selection baselines
    drop some;
  * the actual uploaded byte fraction tracks A_server;
  * heterogeneous sub-models aggregate without shape errors.
"""

import dataclasses

import jax
import numpy as np
import pytest

from repro.core import ProtocolConfig, FedDDServer, run_scheme
from repro.core.protocol import RunResult
from repro.data import (label_coverage_score, make_dataset,
                        partition_noniid_b)
from repro.fl import (MLP_SPEC, HETERO_A_SPECS, init_cnn_spec,
                      make_eval_fn, make_local_train_fn, model_bytes,
                      sample_system_telemetry)


@pytest.fixture(scope="module")
def fl_setup():
    train, test = make_dataset("mnist", num_train=3000, num_test=800, seed=0)
    n = 8
    parts = partition_noniid_b(train, n, seed=0)
    params = init_cnn_spec(jax.random.PRNGKey(0), MLP_SPEC)
    tel = sample_system_telemetry(
        n, [model_bytes(params)] * n, [len(p) for p in parts],
        [label_coverage_score(train, p) for p in parts], seed=0)
    ltf = make_local_train_fn(MLP_SPEC, train, parts, flatten=True, lr=0.1)
    ef = make_eval_fn(MLP_SPEC, test, flatten=True)
    return params, tel, ltf, ef


def _run(scheme, fl_setup, rounds=6, **kw) -> RunResult:
    params, tel, ltf, ef = fl_setup
    return run_scheme(scheme, params, tel, ltf, ef, rounds=rounds,
                      a_server=0.6, h=5, seed=0, **kw)


def test_feddd_all_clients_participate(fl_setup):
    res = _run("feddd", fl_setup, rounds=2)
    assert all(r.participants == 8 for r in res.history)


def test_client_selection_drops_clients(fl_setup):
    res = _run("fedcs", fl_setup, rounds=2)
    assert all(r.participants < 8 for r in res.history)


def test_uploaded_fraction_tracks_budget(fl_setup):
    res = _run("feddd", fl_setup, rounds=3)
    # round 1 has D=0 (Algorithm 1 initialisation) -> full upload; from
    # round 2 on the optimized rates apply.
    for r in res.history[1:]:
        assert r.uploaded_fraction == pytest.approx(0.6, abs=0.08)


def test_feddd_faster_than_fedavg_to_target(fl_setup):
    feddd = _run("feddd", fl_setup, rounds=6)
    fedavg = _run("fedavg", fl_setup, rounds=6)
    target = 0.9
    t_dd = feddd.time_to_accuracy(target)
    t_avg = fedavg.time_to_accuracy(target)
    assert t_dd is not None
    if t_avg is not None:
        assert t_dd < t_avg


def test_epsilon_tracking(fl_setup):
    res = _run("feddd", fl_setup, rounds=3, track_epsilon=True)
    eps = [r.epsilon for r in res.history]
    assert all(e is not None and e >= 0 for e in eps)
    # round 1 uploads everything (D=0) -> eps ~ 0
    assert eps[0] < 1e-6


def test_heterogeneous_submodels_aggregate():
    """HeteroFL-style width-pruned sub-models train + aggregate (Table 3)."""
    train, test = make_dataset("cifar10", num_train=1200, num_test=300,
                               seed=1)
    n = 5
    parts = partition_noniid_b(train, n, seed=1)
    specs = HETERO_A_SPECS
    clients = [init_cnn_spec(jax.random.PRNGKey(i), s)
               for i, s in enumerate(specs)]
    global_params = init_cnn_spec(jax.random.PRNGKey(0), specs[0])
    tel = sample_system_telemetry(
        n, [model_bytes(p) for p in clients],
        [len(p) for p in parts],
        [label_coverage_score(train, p) for p in parts], seed=1)
    fns = [make_local_train_fn(specs[i], train, parts, lr=0.05)
           for i in range(n)]

    def ltf(params, idx, rng):
        return fns[idx](params, idx, rng)

    cfg = ProtocolConfig(scheme="feddd", rounds=2, a_server=0.6, h=5)
    server = FedDDServer(global_params, cfg, tel, client_params=clients)
    assert server.heterogeneous
    res = server.run(ltf, rounds=2)
    assert len(res.history) == 2
    for (path, g), (_, g0) in zip(
            jax.tree_util.tree_flatten_with_path(res.global_params)[0],
            jax.tree_util.tree_flatten_with_path(global_params)[0]):
        assert g.shape == g0.shape
    assert np.isfinite(res.history[-1].mean_loss)


def test_selection_variant_schemes_run(fl_setup):
    from repro.core.selection import SelectionConfig
    params, tel, ltf, ef = fl_setup
    for scheme in ("random", "max", "delta", "ordered"):
        res = run_scheme("feddd", params, tel, ltf, None, rounds=2,
                         a_server=0.6, h=5,
                         selection=SelectionConfig(scheme=scheme))
        assert len(res.history) == 2
