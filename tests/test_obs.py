"""Observability layer (repro/obs): the disabled-obs bit-identity
contract, the enabled-obs no-extra-transfer contract, and the pieces —
metrics registry, JSONL run log round-trips, fault incident events, and
the run-inspection CLI.

Pins the observability axis's contracts (mirroring the zero-rate-faults
contract of tests/test_faults.py):

* inert default — ``ObsConfig()`` resolves to the shared NULL_RECORDER
  and leaves learning state BIT-IDENTICAL on all four execution paths
  (reference loop / batched engine / grouped engine / scanned engine)
  and the event-driven simulator;
* no new syncs — enabling JSONL logging performs the same number of
  ``jax.device_get`` calls as a disabled run, and triggers no engine
  recompilation (the ``jax.named_scope`` annotations are unconditional
  compile-time metadata);
* run-log fidelity — the JSONL log round-trips to the identical
  RoundRecord history (float64 repr exactness), fault incidents appear
  as one event each, and byte counters equal the history sums;
* RoundRecord invariants — wire/uploaded consistency and zeroed
  failure-economy fields on every fault-free path;
* the report CLI renders phase/byte/failure/straggler sections from a
  real log and exports CSV + Prometheus text.
"""

import json
from pathlib import Path

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import FedDDServer, ProtocolConfig, run_scheme
from repro.core.allocation import ClientTelemetry
from repro.obs import (NULL_RECORDER, MetricsRegistry, ObsConfig,
                       make_recorder, load_history, read_events)
from repro.obs import report as obs_report
from repro.sim import FaultConfig, RandomFaults, ScriptedFaults, SimConfig, \
    run_sim

pytestmark = pytest.mark.flcore


# --- shared fixtures ---------------------------------------------------------

def _params(key, w=12):
    k1, k2 = jax.random.split(key)
    return {"fc0": {"w": jax.random.normal(k1, (20, w)), "b": jnp.zeros(w)},
            "fc1": {"w": jax.random.normal(k2, (w, 5)), "b": jnp.zeros(5)}}


def _nbytes(p):
    return float(sum(l.size * l.dtype.itemsize
                     for l in jax.tree_util.tree_leaves(p)))


def _tel(n, nbytes, seed=0):
    rng = np.random.default_rng(seed)
    return ClientTelemetry(
        model_bytes=np.full(n, nbytes) if np.isscalar(nbytes)
        else np.asarray(nbytes),
        uplink_rate=rng.uniform(1e3, 5e3, n),
        downlink_rate=rng.uniform(5e3, 2e4, n),
        compute_latency=rng.uniform(1.0, 5.0, n),
        num_samples=rng.integers(10, 50, n).astype(float),
        label_coverage=rng.uniform(0.5, 1.0, n),
        train_loss=np.ones(n))


def _ltf(p, idx, key):
    """Deterministic pseudo-training (no dataset needed)."""
    return (jax.tree_util.tree_map(
        lambda x: x * 0.99 + 0.01 * jax.random.normal(key, x.shape), p),
        1.0 / (idx + 1.0))


def _trees_equal(a, b):
    return all(bool(jnp.all(x == y)) for x, y in zip(
        jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)))


def _histories_equal(ha, hb):
    assert len(ha) == len(hb)
    for ra, rb in zip(ha, hb):
        assert ra.round == rb.round
        assert ra.mean_loss == rb.mean_loss
        assert ra.sim_time == rb.sim_time
        assert ra.uploaded_bytes == rb.uploaded_bytes
        assert ra.wire_bytes == rb.wire_bytes
        assert ra.participants == rb.participants
        np.testing.assert_array_equal(ra.dropout_rates, rb.dropout_rates)


def _ragged_fleet(n=6, seed=0):
    widths = (12, 8, 6)
    gp = _params(jax.random.PRNGKey(seed), max(widths))
    clients = [_params(jax.random.PRNGKey(seed + 100 + i),
                       widths[i % len(widths)]) for i in range(n)]
    return gp, clients


def _scan_fixture(n=8, seed=0):
    params = _params(jax.random.PRNGKey(seed))
    tel = _tel(n, _nbytes(params), seed=seed)

    @jax.jit
    def batched(stacked, key):
        new = jax.tree_util.tree_map(
            lambda x: x * 0.99 + 0.01 * jax.random.normal(
                jax.random.fold_in(key, 1), x.shape), stacked)
        l0 = jax.tree_util.tree_leaves(new)[0]
        losses = jnp.mean(jnp.abs(l0.reshape(l0.shape[0], -1)), axis=1)
        return new, losses

    return params, tel, batched


# --- metrics registry --------------------------------------------------------

def test_registry_counter_gauge_labels():
    reg = MetricsRegistry()
    reg.inc("req_total", 1, path="a")
    reg.inc("req_total", 2, path="a")
    reg.inc("req_total", 5, path="b")
    reg.set("temp", 3.5, room="x")
    reg.set("temp", 4.5, room="x")          # gauges overwrite
    assert reg.value("req_total", path="a") == 3.0
    assert reg.value("req_total", path="b") == 5.0
    assert reg.value("temp", room="x") == 4.5
    with pytest.raises(ValueError):
        reg.inc("req_total", -1, path="a")  # counters only go up
    with pytest.raises(ValueError):
        reg.set("req_total", 1.0)           # kind conflict


def test_registry_histogram_prometheus_cumulative():
    reg = MetricsRegistry()
    for v in (0.002, 0.002, 0.7, 100.0):
        reg.observe("lat_seconds", v)
    text = reg.prometheus_text()
    lines = {l.split(" ")[0]: float(l.split(" ")[1])
             for l in text.splitlines() if l.startswith("lat_seconds")}
    assert lines['lat_seconds_bucket{le="+Inf"}'] == 4.0
    assert lines['lat_seconds_count'] == 4.0
    assert lines['lat_seconds_sum'] == pytest.approx(100.704)
    assert lines['lat_seconds_bucket{le="0.005"}'] == 2.0
    assert lines['lat_seconds_bucket{le="1"}'] == 3.0
    # cumulative counts are monotone non-decreasing in file (= le) order
    les = [float(l.split(" ")[1]) for l in text.splitlines()
           if "_bucket" in l]
    assert all(a <= b for a, b in zip(les, les[1:]))


def test_registry_csv_rows():
    reg = MetricsRegistry()
    reg.inc("n_total", 2, k="v")
    rows = reg.csv_rows()
    assert rows[0] == "metric,labels,value"
    assert any("n_total" in r and "k=v" in r for r in rows[1:])


# --- inert default -----------------------------------------------------------

def test_default_obsconfig_is_inert():
    assert ObsConfig().active is False
    assert make_recorder(ObsConfig(), driver="x") is NULL_RECORDER
    assert make_recorder(None, driver="x") is NULL_RECORDER
    # any field set activates
    assert ObsConfig(enabled=True).active
    assert ObsConfig(jsonl_path="/tmp/x").active
    assert ObsConfig(trace=True).active
    assert ObsConfig(registry=MetricsRegistry()).active
    # the null recorder's hooks are callable no-ops
    with NULL_RECORDER.span("phase"):
        pass
    NULL_RECORDER.event("x", kind="collides_fine")
    NULL_RECORDER.close()


# --- bit-identity: obs-on == obs-off on every path ---------------------------

def _run_path(path, obs, tmp_path):
    n = 6
    kw = dict(rounds=4, a_server=0.6, h=3, seed=0)
    if obs:
        kw["obs"] = ObsConfig(enabled=True,
                              jsonl_path=str(tmp_path / f"{path}.jsonl"))
    if path == "loop":
        params = _params(jax.random.PRNGKey(0))
        return run_scheme("feddd", params, _tel(n, _nbytes(params)), _ltf,
                          None, batched=False, **kw)
    if path == "engine":
        params = _params(jax.random.PRNGKey(0))
        return run_scheme("feddd", params, _tel(n, _nbytes(params)), _ltf,
                          None, batched=True, **kw)
    if path == "grouped":
        gp, clients = _ragged_fleet(n)
        tel = _tel(n, [_nbytes(p) for p in clients])
        return run_scheme("feddd", gp, tel, _ltf, None,
                          client_params=clients, **kw)
    if path == "scanned":
        params, tel, batched = _scan_fixture()
        cfg = ProtocolConfig(scheme="feddd", allocator="jax",
                             rounds_per_dispatch=2, **kw)
        return FedDDServer(params, cfg, tel).run(batched_train_fn=batched)
    if path == "sim":
        params = _params(jax.random.PRNGKey(0))
        return run_sim("feddd", params, _tel(n, _nbytes(params)), _ltf,
                       None, sim=SimConfig(policy="sync"),
                       faults=RandomFaults(FaultConfig(
                           crash_rate=0.2, loss_rate=0.3, seed=0)), **kw)
    raise AssertionError(path)


@pytest.mark.parametrize("path", ["loop", "engine", "grouped", "scanned",
                                  "sim"])
def test_obs_enabled_is_bit_identical(path, tmp_path):
    """THE acceptance contract: enabling observability (with a JSONL log)
    changes no learning state on any execution path."""
    ref = _run_path(path, False, tmp_path)
    got = _run_path(path, True, tmp_path)
    assert _trees_equal(ref.global_params, got.global_params)
    _histories_equal(ref.history, got.history)


def test_obs_disabled_leaves_null_recorder(tmp_path):
    params = _params(jax.random.PRNGKey(0))
    srv = FedDDServer(params, ProtocolConfig(scheme="feddd", rounds=2),
                      _tel(4, _nbytes(params)))
    srv.run(_ltf)
    assert srv.obs is NULL_RECORDER


# --- no new device->host transfers, no recompiles ----------------------------

def test_obs_enabled_adds_no_device_transfers(tmp_path, monkeypatch):
    """Recording consumes only host data the run already pulls: the
    number of ``jax.device_get`` calls is identical obs-on vs obs-off."""
    counts = {"n": 0}
    real = jax.device_get

    def counting(x):
        counts["n"] += 1
        return real(x)

    monkeypatch.setattr(jax, "device_get", counting)
    _run_path("engine", False, tmp_path)
    off = counts["n"]
    counts["n"] = 0
    _run_path("engine", True, tmp_path)
    assert counts["n"] == off


def test_obs_enabled_triggers_no_recompile(tmp_path):
    """The named_scope phase annotations are unconditional compile-time
    metadata: an obs-on run reuses the obs-off engine compile."""
    from repro.core.round_engine import _round_step

    _run_path("engine", False, tmp_path)          # warm the jit cache
    warm = _round_step._cache_size()
    _run_path("engine", True, tmp_path)
    assert _round_step._cache_size() == warm


# --- JSONL run log -----------------------------------------------------------

def test_jsonl_roundtrips_history_exactly(tmp_path):
    res = _run_path("engine", True, tmp_path)
    hist = load_history(str(tmp_path / "engine.jsonl"))
    assert len(hist) == len(res.history)
    for a, b in zip(res.history, hist):
        assert a.round == b.round
        assert a.mean_loss == b.mean_loss          # float64 repr exact
        assert a.sim_time == b.sim_time
        assert a.uploaded_bytes == b.uploaded_bytes
        assert a.wire_bytes == b.wire_bytes
        assert a.host_wall_time == b.host_wall_time
        np.testing.assert_array_equal(np.asarray(a.dropout_rates),
                                      np.asarray(b.dropout_rates))


def test_jsonl_schema_and_event_stream(tmp_path):
    _run_path("engine", True, tmp_path)
    events = read_events(str(tmp_path / "engine.jsonl"))
    assert events[0]["event"] == "run_start"
    assert events[0]["driver"] == "protocol"
    assert events[-1]["event"] == "run_end"
    kinds = {e["event"] for e in events}
    assert {"span", "round"} <= kinds
    spans = {e["name"] for e in events if e["event"] == "span"}
    assert {"local_train", "engine_step", "host_transfer",
            "allocate"} <= spans
    rounds = [e for e in events if e["event"] == "round"]
    assert [e["round"] for e in rounds] == [1, 2, 3, 4]
    assert all(e["path"] == "engine" and e["scheme"] == "feddd"
               for e in rounds)


def test_registry_totals_match_history(tmp_path):
    """The account_uplink hook feeds the byte counters exactly once per
    round: registry totals == history sums."""
    reg = MetricsRegistry()
    params = _params(jax.random.PRNGKey(0))
    res = run_scheme("feddd", params, _tel(6, _nbytes(params)), _ltf, None,
                     rounds=3, a_server=0.6, h=3, seed=0,
                     obs=ObsConfig(enabled=True, registry=reg))
    assert reg.value("feddd_uploaded_bytes_total") == pytest.approx(
        sum(r.uploaded_bytes for r in res.history))
    assert reg.value("feddd_wire_bytes_total") == pytest.approx(
        sum(r.wire_bytes for r in res.history))
    assert reg.value("feddd_rounds_total", scheme="feddd",
                     path="engine") == 3.0


# --- RoundRecord invariants (fault-free, all four paths) ---------------------

@pytest.mark.parametrize("path", ["loop", "engine", "grouped", "scanned"])
def test_round_record_invariants(path, tmp_path):
    res = _run_path(path, False, tmp_path)
    for r in res.history:
        # default dense comm charges exactly the analytic bytes
        assert r.wire_bytes == r.uploaded_bytes
        assert r.uploaded_bytes > 0.0
        # failure economy is all-zero without a fault model
        assert r.survivors == r.participants
        assert r.retries == 0
        assert r.abandoned_bytes == 0.0
        assert r.quarantined_bytes == 0.0
        assert not r.skipped


# --- fault incidents ---------------------------------------------------------

def test_fault_incident_events(tmp_path):
    """A scripted crash surfaces as exactly one fault event with the
    incident's own kind; a quorum skip logs the skipped round and the
    skip incident, and the skipped record round-trips."""
    n = 4
    params = _params(jax.random.PRNGKey(0))
    tel = _tel(n, _nbytes(params))
    log = tmp_path / "faults.jsonl"
    # fault epochs are 0-indexed rounds: epoch 1 -> logged round 2
    # (client 1 crashes); epoch 2 -> round 3 (all crash -> quorum skip)
    faults = ScriptedFaults(
        crashes={(1, 1): 0.5, **{(2, i): 0.5 for i in range(n)}},
        config=FaultConfig(quorum=1))
    res = run_sim("feddd", params, tel, _ltf, None,
                  sim=SimConfig(policy="sync"), faults=faults,
                  rounds=3, a_server=0.6, h=3, seed=0,
                  obs=ObsConfig(enabled=True, jsonl_path=str(log)))
    events = read_events(str(log))
    crashes = [e for e in events
               if e["event"] == "fault" and e["kind"] == "crash"]
    assert len(crashes) == 1 + n
    assert any(e["round"] == 2 and e["client"] == 1 for e in crashes)
    skips = [e for e in events
             if e["event"] == "fault" and e["kind"] == "quorum_skip"]
    assert len(skips) == 1 and skips[0]["round"] == 3
    assert res.history[-1].skipped
    hist = load_history(str(log))
    assert hist[-1].skipped and hist[-1].survivors == 0


# --- report CLI --------------------------------------------------------------

def test_report_cli_renders_and_exports(tmp_path, capsys):
    _run_path("sim", True, tmp_path)
    log = str(tmp_path / "sim.jsonl")
    csv = tmp_path / "rounds.csv"
    prom = tmp_path / "metrics.prom"
    rc = obs_report.main([log, "--csv", str(csv), "--prom", str(prom)])
    assert rc == 0
    out = capsys.readouterr().out
    for section in ("Phase breakdown", "Byte economy", "Failure economy",
                    "Straggler timeline"):
        assert section in out, section
    assert "local_train" in out
    # CSV: header + one line per non-skipped... every round logs one row
    lines = csv.read_text().strip().splitlines()
    assert lines[0].startswith("round,")
    assert len(lines) == 1 + 4
    # Prometheus replay uses the same round->metrics mapping as live runs
    ptext = prom.read_text()
    assert "feddd_rounds_total" in ptext
    assert "feddd_sim_time_seconds" in ptext


def test_report_cli_rejects_non_runlog(tmp_path):
    bad = tmp_path / "bad.jsonl"
    bad.write_text('{"event":"round"}\n')
    with pytest.raises(ValueError):
        obs_report.main([str(bad)])


# --- committed benchmark baseline (CI regression gate input) -----------------

def test_bench_trajectory_present():
    """results/BENCH_round_engine.json is a committed artifact the CI
    perf gate diffs against — its absence must fail loudly, not skip."""
    path = Path(__file__).resolve().parents[1] / "results" / \
        "BENCH_round_engine.json"
    assert path.exists(), (
        "results/BENCH_round_engine.json missing — regenerate with "
        "`python benchmarks/run.py --json` and commit it")
    payload = json.loads(path.read_text())
    assert "clients" in payload and payload["clients"]
    assert "acceptance" in payload
    for per in payload["clients"].values():
        assert "scanned" in per and "rounds_per_sec" in per["scanned"]


def test_report_renders_outage_windows(tmp_path, capsys):
    """Correlated cell outages appear as an 'Outage windows' section:
    closed windows with durations, open windows flagged, members listed."""
    from repro.sim import CellOutageModel, OutageConfig
    n = 4
    params = _params(jax.random.PRNGKey(0))
    tel = _tel(n, _nbytes(params))
    log = tmp_path / "outages.jsonl"
    # p_out = p_back = 1: cells alternate down/up from epoch 1, so the
    # log holds one closed window (duration 1) and one still open
    run_sim("feddd", params, tel, _ltf, None,
            sim=SimConfig(policy="sync"),
            faults=CellOutageModel(
                n, OutageConfig(cells=2, p_out=1.0, p_back=1.0)),
            rounds=4, a_server=0.6, h=3, seed=0,
            obs=ObsConfig(enabled=True, jsonl_path=str(log)))
    rc = obs_report.main([str(log)])
    assert rc == 0
    out = capsys.readouterr().out
    assert "Outage windows" in out
    assert "cell 0" in out and "cell 1" in out
    assert "epoch down" in out            # a closed window with duration
    assert "still down at end" in out     # an open window
    assert "members 0,2" in out           # round-robin cell 0 of n=4
    # a log with no outage incidents renders no outage section
    clean = tmp_path / "clean.jsonl"
    run_sim("feddd", params, tel, _ltf, None,
            sim=SimConfig(policy="sync"),
            rounds=2, a_server=0.6, h=3, seed=0,
            obs=ObsConfig(enabled=True, jsonl_path=str(clean)))
    obs_report.main([str(clean)])
    assert "Outage windows" not in capsys.readouterr().out
