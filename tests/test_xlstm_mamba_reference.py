"""Reference-recurrence tests: the chunkwise-parallel mLSTM and chunked
Mamba scan must match naive step-by-step recurrences (fp64-ish fp32)."""

import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import ssm, xlstm
from repro.models.config import MambaConfig, XLSTMConfig


def _naive_mlstm(q, k, v, log_i, log_f):
    """Exact stabilised recurrence, one step at a time.  Shapes:
    q,k,v (B,S,H,hd); gates (B,S,H)."""
    b, s, h, hd = q.shape
    c = np.zeros((b, h, hd, hd), np.float64)
    n = np.zeros((b, h, hd), np.float64)
    m = np.full((b, h), -1e30, np.float64)
    outs = []
    qn, kn, vn = (np.asarray(t, np.float64) for t in (q, k, v))
    li, lf = np.asarray(log_i, np.float64), np.asarray(log_f, np.float64)
    for t in range(s):
        m_new = np.maximum(lf[:, t] + m, li[:, t])
        fs = np.exp(lf[:, t] + m - m_new)
        is_ = np.exp(li[:, t] - m_new)
        c = fs[..., None, None] * c + is_[..., None, None] * (
            kn[:, t][..., :, None] * vn[:, t][..., None, :])
        n = fs[..., None] * n + is_[..., None] * kn[:, t]
        num = np.einsum("bhd,bhde->bhe", qn[:, t], c)
        den = np.abs(np.einsum("bhd,bhd->bh", qn[:, t], n))
        outs.append(num / np.maximum(den, np.exp(-m_new))[..., None])
        m = m_new
    return np.stack(outs, 1)


@pytest.mark.parametrize("chunk", [1, 3, 8, 64])
def test_mlstm_chunkwise_matches_naive(chunk):
    b, s, h, hd = 2, 24, 2, 8
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 5)
    q = jax.random.normal(ks[0], (b, s, h, hd)) / math.sqrt(hd)
    k = jax.random.normal(ks[1], (b, s, h, hd))
    v = jax.random.normal(ks[2], (b, s, h, hd))
    log_i = jax.random.normal(ks[3], (b, s, h))
    log_f = -jax.nn.softplus(-jax.random.normal(ks[4], (b, s, h)) - 2.0)

    want = _naive_mlstm(q, k, v, log_i, log_f)

    # run the chunked path
    n_chunks = (s + chunk - 1) // chunk
    pad = n_chunks * chunk - s
    def _p(t):
        t = jnp.pad(t, ((0, 0), (0, pad)) + ((0, 0),) * (t.ndim - 2))
        return jnp.moveaxis(t.reshape((b, n_chunks, chunk) + t.shape[2:]),
                            1, 0)
    st = xlstm.MLSTMState.zeros(b, get_config("xlstm_1p3b", reduced=True))
    # rebuild state with right head dims
    st = xlstm.MLSTMState(c=jnp.zeros((b, h, hd, hd)),
                          n=jnp.zeros((b, h, hd)),
                          m=jnp.full((b, h), -1e30))
    outs = []
    for ci in range(n_chunks):
        sl = slice(ci * chunk, (ci + 1) * chunk)
        qs = jnp.pad(q[:, sl], ((0,0),(0,chunk-q[:, sl].shape[1]),(0,0),(0,0)))
        kss = jnp.pad(k[:, sl], ((0,0),(0,chunk-k[:, sl].shape[1]),(0,0),(0,0)))
        vs = jnp.pad(v[:, sl], ((0,0),(0,chunk-v[:, sl].shape[1]),(0,0),(0,0)))
        lis = jnp.pad(log_i[:, sl], ((0,0),(0,chunk-log_i[:, sl].shape[1]),(0,0)))
        lfs = jnp.pad(log_f[:, sl], ((0,0),(0,chunk-log_f[:, sl].shape[1]),(0,0)))
        st, hout = xlstm._mlstm_chunk(st, qs, kss, vs, lis, lfs)
        outs.append(hout)
    got = np.asarray(jnp.concatenate(outs, 1))[:, :s]
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("chunk", [1, 4, 16, 256])
def test_mamba_chunked_scan_matches_naive(chunk):
    cfg = get_config("jamba_1p5_large_398b", reduced=True)
    cfg = dataclasses.replace(cfg, d_model=32,
                              mamba=MambaConfig(d_state=4, d_conv=4,
                                                expand=2))
    key = jax.random.PRNGKey(1)
    p = ssm.init_mamba(key, cfg, jnp.float32)
    b, s = 2, 19
    x = jax.random.normal(jax.random.fold_in(key, 1), (b, s, cfg.d_model))
    y_chunk = ssm.mamba_forward(p, cfg, x, chunk=chunk)
    # naive: decode step by step
    st = ssm.MambaState.zeros(b, cfg, jnp.float32)
    outs = []
    for t in range(s):
        o, st = ssm.mamba_decode(p, cfg, x[:, t:t + 1], st)
        outs.append(o)
    y_naive = jnp.concatenate(outs, 1)
    np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_naive),
                               rtol=3e-4, atol=3e-4)


def test_slstm_decode_matches_forward():
    cfg = get_config("xlstm_1p3b", reduced=True)
    cfg = dataclasses.replace(cfg, d_model=32, param_dtype="float32")
    key = jax.random.PRNGKey(2)
    p = xlstm.init_slstm(key, cfg, jnp.float32)
    b, s = 2, 9
    x = jax.random.normal(jax.random.fold_in(key, 3), (b, s, cfg.d_model))
    y_fwd = xlstm.slstm_forward(p, cfg, x)
    st = xlstm.SLSTMState.zeros(b, cfg)
    outs = []
    for t in range(s):
        o, st = xlstm.slstm_decode(p, cfg, x[:, t:t + 1], st)
        outs.append(o)
    y_dec = jnp.concatenate(outs, 1)
    np.testing.assert_allclose(np.asarray(y_dec), np.asarray(y_fwd),
                               rtol=2e-4, atol=2e-4)
