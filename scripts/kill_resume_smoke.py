#!/usr/bin/env python
"""Kill-and-resume smoke driver (CI survivability lane).

Runs the same faulty, obs-logged simulation three times in child
processes:

* ``full``   — uninterrupted reference run;
* ``crash``  — checkpointing every round, SIGKILL'd (uncatchable)
  mid-round 4 via its own eval hook;
* ``resume`` — restarted from the last atomic snapshot the crashed
  process managed to write.

The run digest (sha256 over the event trace, per-round records, dropout
rates, and final global params) of ``resume`` must equal ``full``
byte-for-byte — the crash-resume contract of
``repro.checkpoint.run_state`` (pinned in tests/test_resume.py; this
script is the CI smoke that also leaves the artifacts behind).

::

    PYTHONPATH=src python scripts/kill_resume_smoke.py \
        [--out-dir results/kill_resume]

Writes ``full.jsonl`` / ``crash.jsonl`` / ``resume.jsonl`` run logs, the
surviving ``ck.npz`` snapshot (+ sidecar), and a ``summary.json`` with
the digests and verdict into the output dir (uploaded as a CI
artifact); exits non-zero on any contract violation.
"""

from __future__ import annotations

import argparse
import json
import os
import signal
import subprocess
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parents[1]
N, ROUNDS, CRASH_AT_EVAL = 5, 6, 4


def _child(mode: str, ckpt_path: str, log_path: str) -> None:
    """One simulation run; prints the run digest (never returns in
    ``crash`` mode — the process SIGKILLs itself mid-round)."""
    import hashlib

    import numpy as np
    import jax
    import jax.numpy as jnp

    from repro.core.allocation import ClientTelemetry
    from repro.obs import ObsConfig
    from repro.sim import (CellOutageModel, FaultConfig, OutageConfig,
                           RandomFaults, SimConfig, run_sim)

    def params():
        k1, k2 = jax.random.split(jax.random.PRNGKey(0))
        return {"fc0": {"w": jax.random.normal(k1, (20, 12)),
                        "b": jnp.zeros(12)},
                "fc1": {"w": jax.random.normal(k2, (12, 5)),
                        "b": jnp.zeros(5)}}

    def tel():
        rng = np.random.default_rng(0)
        nbytes = float(sum(l.size * l.dtype.itemsize
                           for l in jax.tree_util.tree_leaves(params())))
        return ClientTelemetry(
            model_bytes=np.full(N, nbytes),
            uplink_rate=rng.uniform(1e3, 5e3, N),
            downlink_rate=rng.uniform(5e3, 2e4, N),
            compute_latency=rng.uniform(1.0, 5.0, N),
            num_samples=rng.integers(10, 50, N).astype(float),
            label_coverage=rng.uniform(0.5, 1.0, N),
            train_loss=np.ones(N))

    def ltf(p, idx, key):
        return (jax.tree_util.tree_map(
            lambda x: x * 0.99 + 0.01 * jax.random.normal(key, x.shape),
            p), 1.0 / (idx + 1.0))

    calls = []

    def eval_fn(p):
        calls.append(1)
        if mode == "crash" and len(calls) == CRASH_AT_EVAL:
            os.kill(os.getpid(), signal.SIGKILL)
        return {"probe": float(jnp.sum(p["fc1"]["b"]))}

    faults = CellOutageModel(
        N, OutageConfig(cells=2, p_out=0.3, p_back=0.5, seed=3),
        inner=RandomFaults(FaultConfig(crash_rate=0.15, loss_rate=0.1,
                                       seed=5)))
    kw = dict(sim=SimConfig(policy="sync"), faults=faults, rounds=ROUNDS,
              a_server=0.6, h=2, seed=0,
              obs=ObsConfig(enabled=True, jsonl_path=log_path))
    if mode in ("crash", "resume"):
        kw.update(checkpoint_every=1, checkpoint_path=ckpt_path)
    if mode == "resume":
        kw.update(resume_from=ckpt_path)

    res = run_sim("feddd", params(), tel(), ltf, eval_fn, **kw)

    h = hashlib.sha256()
    times = np.asarray([e[0] for e in res.event_trace])
    h.update(times.tobytes())
    h.update(",".join(f"{e[1]}:{e[2]}" for e in res.event_trace).encode())
    rec = np.asarray([[r.sim_time, r.mean_loss, r.participants,
                       r.survivors, r.retries, r.abandoned_bytes,
                       float(r.skipped)] for r in res.history])
    h.update(rec.tobytes())
    h.update(np.concatenate([np.asarray(r.dropout_rates)
                             for r in res.history]).tobytes())
    for leaf in jax.tree_util.tree_leaves(res.global_params):
        h.update(np.asarray(leaf).tobytes())
    print(h.hexdigest())


def _spawn(mode: str, out_dir: Path) -> subprocess.CompletedProcess:
    env = dict(os.environ)
    env.setdefault("JAX_PLATFORMS", "cpu")
    env["PYTHONPATH"] = (str(REPO / "src") + os.pathsep
                         + env.get("PYTHONPATH", "")).rstrip(os.pathsep)
    return subprocess.run(
        [sys.executable, __file__, "--child", mode,
         "--out-dir", str(out_dir)],
        capture_output=True, text=True, env=env, check=False)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default=str(REPO / "results"
                                             / "kill_resume"))
    ap.add_argument("--child", metavar="MODE",
                    choices=("full", "crash", "resume"),
                    help=argparse.SUPPRESS)
    args = ap.parse_args()
    out_dir = Path(args.out_dir)
    out_dir.mkdir(parents=True, exist_ok=True)
    ckpt = out_dir / "ck.npz"

    if args.child:
        _child(args.child, str(ckpt), str(out_dir / f"{args.child}.jsonl"))
        return 0

    failures = []
    full = _spawn("full", out_dir)
    if full.returncode != 0:
        print(full.stderr[-2000:], file=sys.stderr)
        failures.append("full run failed")
    crashed = _spawn("crash", out_dir)
    if crashed.returncode != -signal.SIGKILL:
        failures.append(f"crash child exited {crashed.returncode}, "
                        "expected SIGKILL (-9)")
    if not ckpt.exists():
        failures.append("crashed run left no snapshot behind")
    resumed = _spawn("resume", out_dir)
    if resumed.returncode != 0:
        print(resumed.stderr[-2000:], file=sys.stderr)
        failures.append("resume run failed")

    d_full = full.stdout.strip()
    d_resume = resumed.stdout.strip()
    if not failures and (len(d_full) != 64 or d_full != d_resume):
        failures.append("resumed digest differs from uninterrupted run")
    summary = {
        "rounds": ROUNDS, "clients": N, "crash_at_eval": CRASH_AT_EVAL,
        "digest_full": d_full, "digest_resume": d_resume,
        "ok": not failures, "failures": failures,
    }
    (out_dir / "summary.json").write_text(json.dumps(summary, indent=2))
    print(json.dumps(summary, indent=2))
    if failures:
        return 1
    print("kill-and-resume smoke OK: resumed run is bit-identical")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
